//! The DSE service: a fleet of supervised engine workers, each owning its
//! own [`Session`] (the PJRT executables hold raw C pointers and are
//! deliberately never shared), fed through per-worker bounded deques by a
//! cloneable handle with least-loaded dispatch and work stealing
//! ([`super::fleet`]), with every search tracked as a *job* in the
//! [`JobRegistry`]. All sessions evaluate through one process-shared
//! [`EvalCache`] handle, so tenants probing overlapping design regions
//! hit each other's work no matter which worker serves them.
//!
//! # Jobs
//!
//! Every search — synchronous or not — enters the registry as a job:
//! `submit` answers a `job_id` immediately and the search runs when the
//! engine worker reaches it; the classic synchronous `search`/`batch`
//! requests are submit-plus-wait over the same path, so their wire
//! behaviour is unchanged. Jobs move `queued → running → done |
//! cancelled | failed`; cancellation raises a flag the search polls
//! between evaluation batches (see [`crate::dse::api::SearchCtx`]), so a
//! cancelled job still retains its *partial* outcome. Progress events are
//! published into a single coalescing slot per job (drop-to-latest): a
//! slow watcher never queues unbounded events, it just skips intermediate
//! heartbeats. Terminal jobs are retained for `status` queries up to
//! [`MAX_RETAINED_JOBS`], then garbage-collected oldest-first.
//!
//! # Robustness
//!
//! Every worker is owned by its own supervisor ([`super::supervisor`]): a
//! search that panics is isolated by `catch_unwind` and finalizes its job
//! as `failed` while the worker survives; a worker that dies anyway is
//! restarted with bounded exponential backoff and its in-flight job is
//! retried (up to [`ServiceConfig::max_attempts`] total attempts) or
//! terminally failed — never left `running`. A worker slot that exhausts
//! its restart budget is skipped by dispatch while its siblings keep
//! serving. Admission is bounded fleet-wide by
//! [`ServiceConfig::max_queued`]: over-capacity submits are shed with a
//! structured `overloaded` error carrying a `retry_after_ms` hint.
//! Dropping the [`Service`] (or calling [`Service::shutdown`]) drains
//! gracefully: admissions close, queued jobs cancel terminally, running
//! work gets the drain deadline to stop at a batch boundary, and every
//! watcher wakes. Deterministic fault injection
//! ([`crate::util::fault::FaultPlan`], off by default) drives the chaos
//! suite over exactly these paths.
//!
//! # Batching
//!
//! Generation searches with the `diffaxe` optimizer are **dynamically
//! batched**: the worker drains its deque up to the sampler's fixed batch
//! width (slots can mix workloads and tenants — the sampler conditions
//! per batch element) before issuing one diffusion call, then splits,
//! batch-evaluates, and replies per request. Requests group by
//! *conditioning family* — runtime-conditioned `Runtime` slots share one
//! `sample_runtime` call, while `LlmEdp` and `Structured{Edp,Perf}` slots
//! all condition on the low-EDP class (class 0 + a layer shape) and share
//! one `sample_class` call; a structured request consumes `n_segments`
//! contiguous slots per joint candidate. This is the vLLM-router-style
//! continuous batching adapted to design generation: the expensive
//! fixed-batch executable always runs as full as the queue allows. Every
//! other `(objective, optimizer)` pair — and whole `Batch` requests — run
//! directly on the session between sampler flushes. Batched generation
//! skips the direct paths' candidate dedup: repeat draws are absorbed by
//! the shared eval cache instead.
//!
//! Candidate evaluation goes through the session's memoized, pooled hot
//! path ([`crate::dse::eval`]): recurring rounded design points across
//! requests are served from the sharded eval cache, whose hit/miss counters
//! are mirrored into [`Metrics`] after every evaluation burst.

use super::fleet::Fleet;
use super::metrics::Metrics;
use super::protocol::{ErrorCode, JobInfo, JobState, Request, Response, SearchRequest};
use super::supervisor::{self, Msg, NoEngineError};
use crate::design_space::{
    structured::{constrain, ranges_from_boundaries, segment_layers_by_shape},
    HwConfig,
};
use crate::dse::api::{
    DesignReport, Objective, OptimizerKind, SearchCtx, SearchEvent, SearchOutcome, Session,
    StopReason,
};
use crate::dse::eval::EvalCache;
use crate::dse::structured::{self, StructuredSpec};
use crate::models::{ClassMode, DiffAxE};
use crate::util::fault::{self, FaultPlan, FaultSite};
use crate::util::rng;
use crate::util::sync::{rank, TrackedMutex};
use crate::workload::Gemm;
use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar};
use std::time::{Duration, Instant};

/// Default cap on ranked designs carried in one response (requests can
/// override with `top_k`).
pub const DEFAULT_TOP_K: usize = 64;

/// Terminal jobs retained for `status`/`jobs` queries before GC.
pub const MAX_RETAINED_JOBS: usize = 256;

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// how long the batcher waits to fill a sampler batch
    pub batch_window: Duration,
    /// root seed; per-sampler-call and per-search seeds derive from it via
    /// [`rng::derive`]
    pub seed: u64,
    /// serve the hermetic mock engine instead of compiling artifacts
    /// ([`crate::models::DiffAxE::mock`]) — CI and artifact-free hosts
    pub use_mock_engine: bool,
    /// engine workers in the fleet (least-loaded dispatch with work
    /// stealing; see `coordinator/fleet.rs`). Defaults to available
    /// parallelism capped at [`ServiceConfig::MAX_DEFAULT_WORKERS`];
    /// [`ServiceConfig::mock`] pins `1` so deterministic single-worker
    /// tests keep their serialized dispatch order.
    pub workers: usize,
    /// admission control: jobs queued beyond this *fleet-wide* are shed
    /// with a structured `overloaded` error (and a `retry_after_ms` hint)
    pub max_queued: usize,
    /// total execution attempts per job across worker crashes (`1` means
    /// a job is never retried)
    pub max_attempts: u32,
    /// worker respawns before the supervisor gives up and the service
    /// permanently rejects new work
    pub max_worker_restarts: u32,
    /// base of the exponential worker-respawn backoff
    pub restart_backoff: Duration,
    /// how long shutdown waits for in-flight work before force-cancelling
    pub drain_deadline: Duration,
    /// deterministic fault injection for chaos tests; `None` (production)
    /// costs one pointer check per site
    pub fault_plan: Option<Arc<FaultPlan>>,
}

impl ServiceConfig {
    /// Cap on the default fleet size: past a handful of workers the
    /// continuous batcher's sampler batches thin out, so very wide hosts
    /// should opt in explicitly (`--workers`).
    pub const MAX_DEFAULT_WORKERS: usize = 4;

    pub fn new(artifacts_dir: impl Into<std::path::PathBuf>) -> Self {
        ServiceConfig {
            artifacts_dir: artifacts_dir.into(),
            batch_window: Duration::from_millis(4),
            seed: 1,
            use_mock_engine: false,
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(Self::MAX_DEFAULT_WORKERS),
            max_queued: 256,
            max_attempts: 2,
            max_worker_restarts: 3,
            restart_backoff: Duration::from_millis(50),
            drain_deadline: Duration::from_secs(2),
            fault_plan: None,
        }
    }

    /// A config serving the artifact-free mock engine (engine-kind wire
    /// paths run hermetically; results are deterministic in `seed`). Pins
    /// a single worker so tests that rely on serialized dispatch order
    /// stay deterministic — fleet tests raise `workers` explicitly.
    pub fn mock() -> Self {
        ServiceConfig { use_mock_engine: true, workers: 1, ..ServiceConfig::new("") }
    }
}

// ---------------------------------------------------------------------------
// job registry
// ---------------------------------------------------------------------------

/// Mutable core of one job, guarded by its entry's mutex; the condvar
/// wakes watchers (new event) and waiters (terminal result).
struct JobCore {
    state: JobState,
    /// bumps on every observable change (event published, state change,
    /// terminal result) — watchers resume from the last seq they saw
    seq: u64,
    /// execution attempts: incremented by [`JobRegistry::start`], so `2`
    /// means the job was retried once after a worker crash
    attempts: u32,
    /// the coalescing progress slot: (seq at publish, event). A newer
    /// event *replaces* the buffered one (drop-to-latest backpressure).
    latest: Option<(u64, SearchEvent)>,
    /// terminal response (outcome or error); `Some` ⇔ state is terminal
    result: Option<Response>,
    /// wall-clock from submission to the terminal transition
    elapsed_s: Option<f64>,
}

/// One tracked search job.
pub struct JobEntry {
    num: u64,
    pub id: String,
    pub request: SearchRequest,
    cancel: Arc<AtomicBool>,
    submitted: Instant,
    core: TrackedMutex<JobCore>,
    cv: Condvar,
}

impl JobEntry {
    /// Registry-internal job number, stable across retries and worker
    /// hops; the worker derives the job's deterministic search seed from
    /// it, so a stolen or crash-retried job recomputes the identical
    /// outcome no matter which worker runs it.
    pub(crate) fn num(&self) -> u64 {
        self.num
    }

    /// The shared cancellation flag the running search polls.
    pub fn cancel_flag(&self) -> Arc<AtomicBool> {
        self.cancel.clone()
    }

    /// Current lifecycle state.
    pub fn state(&self) -> JobState {
        self.core.lock().state
    }

    /// Execution attempts so far (0 until the worker first starts it).
    pub fn attempts(&self) -> u32 {
        self.core.lock().attempts
    }

    /// Point-in-time description (the `status` wire unit).
    pub fn info(&self) -> JobInfo {
        let core = self.core.lock();
        let (evals, best_score) = match (&core.result, &core.latest) {
            (Some(Response::Outcome(o)), _) => {
                let best = o.best_score();
                (o.evals, if best.is_finite() { Some(best) } else { None })
            }
            (_, Some((_, ev))) => {
                (ev.evals, if ev.best_score.is_finite() { Some(ev.best_score) } else { None })
            }
            _ => (0, None),
        };
        JobInfo {
            id: self.id.clone(),
            state: core.state,
            optimizer: self.request.optimizer.name().to_string(),
            objective: self.request.objective.to_string(),
            evals,
            best_score,
            attempts: core.attempts,
            elapsed_s: core
                .elapsed_s
                .unwrap_or_else(|| self.submitted.elapsed().as_secs_f64()),
        }
    }

    /// The terminal response if the job already finished (internal error
    /// placeholder otherwise — callers only use this on terminal jobs).
    pub fn result_now(&self) -> Response {
        self.core
            .lock()
            .result
            .clone()
            .unwrap_or_else(|| Response::error(ErrorCode::Internal, "job not finished"))
    }

    /// Block until something newer than `last_seq` is observable. Returns
    /// `(new_seq, fresh_event, terminal)` where `fresh_event` is the
    /// coalesced latest event iff it was published after `last_seq`, and
    /// `terminal` carries the final state + response once the job ends.
    pub fn next_event(
        &self,
        last_seq: u64,
    ) -> (u64, Option<SearchEvent>, Option<(JobState, Response)>) {
        let mut core = self.core.lock();
        while core.seq <= last_seq && core.result.is_none() {
            core = core.wait(&self.cv);
        }
        let ev = core.latest.as_ref().filter(|(s, _)| *s > last_seq).map(|(_, e)| *e);
        let terminal = core.result.clone().map(|r| (core.state, r));
        (core.seq, ev, terminal)
    }

    /// Non-blocking [`JobEntry::next_event`]: the watch reactor's single
    /// event thread polls this instead of parking a thread per watcher.
    pub fn poll_event(
        &self,
        last_seq: u64,
    ) -> (u64, Option<SearchEvent>, Option<(JobState, Response)>) {
        let core = self.core.lock();
        let ev = core.latest.as_ref().filter(|(s, _)| *s > last_seq).map(|(_, e)| *e);
        let terminal = core.result.clone().map(|r| (core.state, r));
        (core.seq, ev, terminal)
    }
}

struct RegistryInner {
    next_id: u64,
    jobs: BTreeMap<u64, Arc<JobEntry>>,
    /// terminal job numbers in completion order (GC queue)
    terminal: VecDeque<u64>,
}

/// Tracks every search job the service has accepted: id allocation,
/// lifecycle transitions (mirrored into [`Metrics`] gauges), progress
/// publication, and bounded retention of finished jobs.
///
/// Lock order: `inner` may take an entry's `core`; an entry's `core` is
/// never held while taking `inner`. The ranks ([`rank::REGISTRY`] <
/// [`rank::JOB_CORE`]) make debug builds assert exactly that — see the
/// lock-rank table in `docs/INVARIANTS.md`.
pub struct JobRegistry {
    inner: TrackedMutex<RegistryInner>,
    metrics: Arc<Metrics>,
    /// chaos-test injection at the [`FaultSite::Finalize`] site; `None`
    /// in production
    faults: Option<Arc<FaultPlan>>,
}

impl JobRegistry {
    pub fn new(metrics: Arc<Metrics>) -> JobRegistry {
        Self::with_faults(metrics, None)
    }

    /// [`JobRegistry::new`] with a fault plan checked at the
    /// [`FaultSite::Finalize`] site (chaos tests; see `util::fault`).
    pub fn with_faults(metrics: Arc<Metrics>, faults: Option<Arc<FaultPlan>>) -> JobRegistry {
        JobRegistry {
            inner: TrackedMutex::new(
                "registry.inner",
                rank::REGISTRY,
                RegistryInner { next_id: 0, jobs: BTreeMap::new(), terminal: VecDeque::new() },
            ),
            metrics,
            faults,
        }
    }

    /// Accept a search as a new queued job.
    pub fn submit(&self, request: SearchRequest) -> Arc<JobEntry> {
        let entry = {
            let mut inner = self.inner.lock();
            inner.next_id += 1;
            let num = inner.next_id;
            let entry = Arc::new(JobEntry {
                num,
                id: format!("job-{num}"),
                request,
                cancel: Arc::new(AtomicBool::new(false)),
                submitted: Instant::now(),
                core: TrackedMutex::new(
                    "job.core",
                    rank::JOB_CORE,
                    JobCore {
                        state: JobState::Queued,
                        seq: 0,
                        attempts: 0,
                        latest: None,
                        result: None,
                        elapsed_s: None,
                    },
                ),
                cv: Condvar::new(),
            });
            inner.jobs.insert(num, entry.clone());
            Self::gc(&mut inner);
            entry
        };
        self.metrics.job_submitted();
        entry
    }

    /// Look a job up by its wire id.
    pub fn get(&self, id: &str) -> Option<Arc<JobEntry>> {
        self.inner.lock().jobs.values().find(|e| e.id == id).cloned()
    }

    /// Every retained job, oldest first.
    pub fn list(&self) -> Vec<JobInfo> {
        self.inner.lock().jobs.values().map(|e| e.info()).collect()
    }

    /// Transition a queued job to running (counting the attempt). False
    /// if the job was cancelled (or otherwise finished) before the worker
    /// reached it.
    pub fn start(&self, entry: &JobEntry) -> bool {
        {
            let mut core = entry.core.lock();
            if core.state != JobState::Queued || core.result.is_some() {
                return false;
            }
            core.state = JobState::Running;
            core.attempts += 1;
            core.seq += 1;
            entry.cv.notify_all();
        }
        self.metrics.job_started();
        true
    }

    /// Return a running job to the queue after a worker crash, keeping
    /// its attempt count. False if the job is not (still) running.
    pub fn requeue(&self, entry: &Arc<JobEntry>) -> bool {
        {
            let mut core = entry.core.lock();
            if core.state != JobState::Running || core.result.is_some() {
                return false;
            }
            core.state = JobState::Queued;
            core.seq += 1;
            entry.cv.notify_all();
        }
        self.metrics.job_requeued();
        true
    }

    /// Publish a progress event into the job's coalescing slot
    /// (drop-to-latest: a buffered event is *replaced*, never queued).
    pub fn publish(&self, entry: &JobEntry, ev: SearchEvent) {
        let was_empty = {
            let mut core = entry.core.lock();
            if core.result.is_some() {
                return;
            }
            let was_empty = core.latest.is_none();
            core.seq += 1;
            core.latest = Some((core.seq, ev));
            entry.cv.notify_all();
            was_empty
        };
        if was_empty {
            self.metrics.event_buffered();
        }
    }

    /// Record a job's terminal state + response. Idempotent: the first
    /// finalization wins (a cancel racing a completion keeps the earlier
    /// result; a detached drain-era worker finishing late cannot regress
    /// a terminal state).
    pub fn finalize(&self, entry: &Arc<JobEntry>, state: JobState, result: Response) {
        debug_assert!(state.terminal());
        if let Some(fp) = &self.faults {
            // the Finalize site has no error return path: error actions
            // are ignored here; panic and delay actions apply
            let _ = fp.check(FaultSite::Finalize);
        }
        let (was_running, had_event);
        {
            let mut core = entry.core.lock();
            if core.result.is_some() {
                return;
            }
            was_running = core.state == JobState::Running;
            had_event = core.latest.is_some();
            core.state = state;
            core.result = Some(result);
            core.elapsed_s = Some(entry.submitted.elapsed().as_secs_f64());
            core.seq += 1;
            entry.cv.notify_all();
        }
        self.metrics.job_finished(state, was_running, had_event);
        let mut inner = self.inner.lock();
        inner.terminal.push_back(entry.num);
        Self::gc(&mut inner);
    }

    /// Raise a job's cancellation flag. A still-queued job becomes
    /// terminal immediately (it never ran, so its outcome is empty); a
    /// running job stops at its next batch boundary and retains the
    /// partial outcome. Returns the post-cancel [`JobInfo`].
    pub fn cancel(&self, id: &str) -> Option<JobInfo> {
        let entry = self.get(id)?;
        entry.cancel.store(true, Ordering::SeqCst);
        let became_terminal = {
            let mut core = entry.core.lock();
            if core.state == JobState::Queued && core.result.is_none() {
                let outcome = SearchOutcome {
                    search_time_s: entry.submitted.elapsed().as_secs_f64(),
                    ..SearchOutcome::empty(
                        entry.request.optimizer.name(),
                        StopReason::Cancelled,
                    )
                };
                core.state = JobState::Cancelled;
                core.result = Some(Response::Outcome(outcome));
                core.elapsed_s = Some(entry.submitted.elapsed().as_secs_f64());
                core.seq += 1;
                entry.cv.notify_all();
                true
            } else {
                false
            }
        };
        if became_terminal {
            self.metrics.job_finished(JobState::Cancelled, false, false);
            let mut inner = self.inner.lock();
            inner.terminal.push_back(entry.num);
            Self::gc(&mut inner);
        }
        Some(entry.info())
    }

    /// Drain fallback: terminally cancel a job regardless of its current
    /// state, with an empty cancelled outcome. Idempotency of
    /// [`JobRegistry::finalize`] makes this safe to race against a
    /// detached worker finishing the same job.
    pub(crate) fn force_cancel(&self, entry: &Arc<JobEntry>) {
        let outcome = SearchOutcome {
            search_time_s: entry.submitted.elapsed().as_secs_f64(),
            ..SearchOutcome::empty(entry.request.optimizer.name(), StopReason::Cancelled)
        };
        self.finalize(entry, JobState::Cancelled, Response::Outcome(outcome));
    }

    fn gc(inner: &mut RegistryInner) {
        while inner.terminal.len() > MAX_RETAINED_JOBS {
            if let Some(num) = inner.terminal.pop_front() {
                inner.jobs.remove(&num);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// handle + service
// ---------------------------------------------------------------------------

/// Cloneable handle to the service. Registry queries (`status`, `cancel`,
/// `jobs`, `metrics`) answer directly — they never queue behind a running
/// search on the engine worker.
#[derive(Clone)]
pub struct Handle {
    fleet: Arc<Fleet>,
    metrics: Arc<Metrics>,
    registry: Arc<JobRegistry>,
}

impl Handle {
    /// Submit a request and block for the response. Synchronous `search`
    /// and `batch` are submit-plus-wait over the job registry.
    pub fn request(&self, request: Request) -> Response {
        let start = Instant::now();
        match request {
            Request::Metrics => {
                let r = Response::MetricsText(self.metrics.snapshot().to_string());
                self.metrics.record_request(start.elapsed().as_secs_f64() * 1e6, 0);
                r
            }
            Request::Jobs => Response::Jobs(self.registry.list()),
            // a watch reaching the blocking path degrades to a status
            // probe; the streaming server intercepts it before this point
            Request::Status { job_id } | Request::Watch { job_id } => {
                match self.registry.get(&job_id) {
                    Some(e) => Response::Job(e.info()),
                    None => unknown_job(&job_id),
                }
            }
            Request::Cancel { job_id } => match self.registry.cancel(&job_id) {
                Some(info) => Response::Job(info),
                None => unknown_job(&job_id),
            },
            Request::Submit(sr) => {
                if let Err(msg) = validate(&sr) {
                    return Response::error(ErrorCode::BadRequest, msg);
                }
                match self.enqueue(sr, None) {
                    Ok(entry) => {
                        Response::Submitted { job_id: entry.id.clone(), state: entry.state() }
                    }
                    Err(rejected) => rejected,
                }
            }
            Request::Search(sr) => {
                if let Err(msg) = validate(&sr) {
                    return Response::error(ErrorCode::BadRequest, msg);
                }
                let (tx, rx) = channel();
                match self.enqueue(sr, Some(tx)) {
                    Ok(_) => rx.recv().unwrap_or_else(|_| {
                        Response::error(ErrorCode::Internal, "service stopped")
                    }),
                    Err(rejected) => rejected,
                }
            }
            Request::Batch(items) => {
                // validate the whole batch before running any item, so a bad
                // pairing cannot discard minutes of completed sibling searches
                for (i, sr) in items.iter().enumerate() {
                    if let Err(msg) = validate(sr) {
                        return Response::error(
                            ErrorCode::BadRequest,
                            format!("batch item {i}: {msg}"),
                        );
                    }
                }
                let rxs: Vec<Receiver<Response>> = items
                    .iter()
                    .map(|sr| {
                        let (tx, rx) = channel();
                        // an admission rejection (queue full, draining)
                        // flows through the same channel as a job result,
                        // so the all-or-nothing collection below applies
                        if let Err(rejected) = self.enqueue(sr.clone(), Some(tx.clone())) {
                            let _ = tx.send(rejected);
                        }
                        rx
                    })
                    .collect();
                let mut outs = Vec::with_capacity(items.len());
                let mut first_err: Option<Response> = None;
                for (i, (sr, rx)) in items.iter().zip(rxs).enumerate() {
                    let resp = rx.recv().unwrap_or_else(|_| {
                        Response::error(ErrorCode::Internal, "service stopped")
                    });
                    match resp {
                        Response::Outcome(o) => outs.push(o),
                        Response::Error { code, message, .. } if first_err.is_none() => {
                            // all-or-nothing by protocol contract (see the
                            // `batch` docs in protocol.rs)
                            first_err = Some(Response::error(
                                code,
                                format!("batch item {i} ({}): {message}", sr.optimizer.name()),
                            ));
                        }
                        _ => {}
                    }
                }
                first_err.unwrap_or(Response::Batch(outs))
            }
        }
    }

    /// Submit without waiting; the receiver yields the response.
    pub fn submit(&self, request: Request) -> Receiver<Response> {
        match request {
            Request::Search(sr) => {
                let (tx, rx) = channel();
                if let Err(msg) = validate(&sr) {
                    let _ = tx.send(Response::error(ErrorCode::BadRequest, msg));
                } else if let Err(rejected) = self.enqueue(sr, Some(tx.clone())) {
                    let _ = tx.send(rejected);
                }
                rx
            }
            other => {
                let (tx, rx) = channel();
                let _ = tx.send(self.request(other));
                rx
            }
        }
    }

    /// Register a job and queue it onto the least-loaded live worker
    /// slot, subject to admission control (fleet-wide queue bound, drain
    /// state, all workers dead).
    fn enqueue(
        &self,
        sr: SearchRequest,
        reply: Option<Sender<Response>>,
    ) -> Result<Arc<JobEntry>, Response> {
        self.fleet.admit(&self.metrics, || self.registry.submit(sr), reply)
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    pub fn registry(&self) -> Arc<JobRegistry> {
        self.registry.clone()
    }
}

fn unknown_job(job_id: &str) -> Response {
    Response::error(ErrorCode::BadRequest, format!("unknown job {job_id:?}"))
}

/// Running service (supervised engine-worker fleet + handle).
pub struct Service {
    pub handle: Handle,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Service {
    /// Start one supervisor (and its first engine worker) per fleet slot.
    /// Blocks until every slot's artifacts are compiled and its engine's
    /// presence is validated (or any fails — a session without an engine
    /// surfaces the typed [`NoEngineError`]), so a returned `Service` is
    /// ready to serve at full capacity. Startup failures are global by
    /// construction (every slot builds the same session), so one failed
    /// slot stops the whole fleet instead of limping.
    pub fn start(cfg: ServiceConfig) -> Result<Service> {
        let metrics = Arc::new(Metrics::new());
        let registry = Arc::new(JobRegistry::with_faults(metrics.clone(), cfg.fault_plan.clone()));
        let workers = cfg.workers.max(1);
        let fleet =
            Fleet::new(workers, cfg.max_queued, cfg.drain_deadline, EvalCache::global_arc());
        metrics.set_workers(workers);
        let mut threads = Vec::with_capacity(workers);
        let mut readies = Vec::with_capacity(workers);
        for slot in 0..workers {
            let (ready_tx, ready_rx) = channel::<Result<()>>();
            let spawned = supervisor::spawn(
                cfg.clone(),
                fleet.clone(),
                slot,
                registry.clone(),
                metrics.clone(),
                ready_tx,
            );
            match spawned {
                Ok(t) => threads.push(t),
                Err(e) => {
                    fleet.begin_stop();
                    for t in threads {
                        let _ = t.join();
                    }
                    return Err(e.into());
                }
            }
            readies.push(ready_rx);
        }
        let mut failed: Option<anyhow::Error> = None;
        for rx in readies {
            let started = rx
                .recv()
                .unwrap_or_else(|_| Err(anyhow::anyhow!("engine worker died during startup")));
            if let Err(e) = started {
                failed.get_or_insert(e);
            }
        }
        if let Some(e) = failed {
            fleet.begin_stop();
            for t in threads {
                let _ = t.join();
            }
            return Err(e);
        }
        Ok(Service { handle: Handle { fleet, metrics, registry }, threads })
    }

    pub fn handle(&self) -> Handle {
        self.handle.clone()
    }

    /// Drain and stop with an explicit deadline for in-flight work:
    /// admissions close immediately, queued jobs cancel terminally,
    /// running jobs get until `deadline` to stop at a batch boundary,
    /// then everything left is force-cancelled so every watcher wakes.
    pub fn shutdown(self, deadline: Duration) {
        self.handle.fleet.set_drain_deadline(deadline);
        // Drop runs the drain
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.handle.fleet.begin_stop();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------------
// engine worker loop
// ---------------------------------------------------------------------------

/// Conditioning family a batched request's sampler slots belong to. One
/// diffusion call serves one family: slots in a `sample_runtime` call all
/// carry `(p_norm, shape)` conditions, slots in a `sample_class` call all
/// carry `(class, shape)` — the batcher packs each family separately and
/// issues at most one call per family per round. Structured work is its
/// own family: every joint candidate's segment conditions must travel in
/// a single `sample_joint` call (one request's budget + segment shapes
/// condition that call), so structured requests never share a sampler
/// call with anything — not even each other.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Family {
    /// runtime-conditioned sampler (`sample_runtime`)
    Runtime,
    /// low-EDP class sampler (`sample_class`, class 0)
    Class,
    /// jointly-conditioned structured sampler (`sample_joint`)
    Structured,
}

/// What one batched generation request asks the sampler for.
enum GenWork {
    /// `Objective::Runtime`: every slot conditions on the normalized
    /// runtime target + the workload shape
    Runtime { g: Gemm, p_norm: f32 },
    /// `Objective::LlmEdp`: candidate base configs from the low-EDP class,
    /// conditioned round-robin over the model's layer shapes (the same
    /// rotation the direct path spreads its budget over)
    Llm { layers: Vec<Gemm>, cursor: usize },
    /// `Objective::Structured{Edp,Perf}`: each joint candidate consumes
    /// `reps.len()` *contiguous* slots of one `sample_joint` call — one
    /// per segment, conditioned on that segment's dominant (max-MACs)
    /// layer under the learned cut points `bounds` — then is constrained
    /// onto the shared budget and evaluated whole-model
    Structured { spec: StructuredSpec, reps: Vec<Gemm>, bounds: Vec<usize> },
}

impl GenWork {
    fn family(&self) -> Family {
        match self {
            GenWork::Runtime { .. } => Family::Runtime,
            GenWork::Llm { .. } => Family::Class,
            GenWork::Structured { .. } => Family::Structured,
        }
    }
}

/// A generation search waiting in the batcher. `acc` collects designs
/// across sampler calls when the request spans batches.
struct PendingGen {
    work: GenWork,
    n: usize,
    top_k: usize,
    objective: Objective,
    acc: Vec<DesignReport>,
    /// per-design segment configurations, parallel to `acc` — populated
    /// only for structured work (the outcome carries the heterogeneous
    /// per-segment configs alongside the envelope reports)
    segs: Vec<Vec<HwConfig>>,
    /// per-design learned segment boundaries, parallel to `segs` —
    /// populated only for structured work with learned cuts
    bounds: Vec<Vec<usize>>,
    /// running best score over `acc` (heartbeats stay O(1) per burst)
    best: f64,
    entry: Arc<JobEntry>,
    /// when the request joined `pending` — the batch-window clock. Queue
    /// wait behind non-batchable jobs must not count against the window,
    /// or a request that sat queued "expires" on arrival and flushes a
    /// batch of one (`entry.submitted` keeps measuring end-to-end
    /// latency).
    joined: Instant,
    reply: Option<Sender<Response>>,
}

impl PendingGen {
    /// Sampler slots this request still needs (a structured request
    /// consumes `n_segments` contiguous slots per joint candidate).
    fn slots_remaining(&self) -> usize {
        let per = match &self.work {
            GenWork::Structured { reps, .. } => reps.len(),
            _ => 1,
        };
        self.n.saturating_sub(self.acc.len()) * per
    }
}

/// Classify a DiffAxE request for the continuous batcher, resolving its
/// conditioning inputs up front. `None` sends it down the direct path:
/// non-generative objectives, a degenerate structured spec (the direct
/// search reports the config error), or a segment count that cannot fit
/// one joint candidate in a sampler call. The caller has already filtered
/// wall-clock-capped requests (the direct path enforces deadlines).
fn gen_work(engine: &DiffAxE, objective: &Objective, gen_batch: usize) -> Option<GenWork> {
    match objective {
        Objective::Runtime { g, target_cycles } => Some(GenWork::Runtime {
            g: *g,
            p_norm: engine.stats.stats_for(g).norm_runtime(*target_cycles),
        }),
        Objective::LlmEdp { model, stage, seq, .. } => {
            let layers = model.layer_gemms(*stage, *seq);
            if layers.is_empty() {
                return None;
            }
            Some(GenWork::Llm { layers, cursor: 0 })
        }
        Objective::StructuredEdp { spec } | Objective::StructuredPerf { spec } => {
            if spec.validate().is_err() {
                return None;
            }
            let s = spec.n_segments();
            if s == 0 || s > gen_batch {
                return None;
            }
            let wl = spec.workload();
            // learned segmentation: cluster layers by shape so segment
            // cuts land on shape-change points, then condition each
            // segment's slots on its dominant layer under those cuts
            let bounds = segment_layers_by_shape(&wl.gemms, s);
            let parts = if bounds.is_empty() {
                structured::partition(wl.gemms.len(), s)
            } else {
                ranges_from_boundaries(&bounds, wl.gemms.len())
            };
            let reps = parts
                .iter()
                .map(|r| {
                    *wl.gemms[r.clone()]
                        .iter()
                        .max_by_key(|g| g.macs())
                        .expect("non-empty segment")
                })
                .collect();
            Some(GenWork::Structured { spec: *spec, reps, bounds })
        }
        Objective::MinEdp { .. } | Objective::MaxPerf { .. } => None,
    }
}

/// Body of one supervised engine worker (thread `diffaxe-engine-{idx}`,
/// serving fleet slot `slot`): build the session, validate it, then
/// dispatch from the slot's deque — stealing from the longest sibling
/// deque when idle — until the drain begins. `ready` is `Some` only for
/// the slot's first worker — it reports the startup result back to
/// [`Service::start`]; respawned workers that fail startup just die and
/// count against the restart budget.
pub(crate) fn worker_main(
    idx: u32,
    cfg: ServiceConfig,
    fleet: Arc<Fleet>,
    slot: usize,
    registry: Arc<JobRegistry>,
    metrics: Arc<Metrics>,
    ready: Option<Sender<Result<()>>>,
) {
    let shared = fleet.slot(slot).clone();
    // fault site: worker startup, before the session exists. A panic
    // action unwinds into the supervisor's death handling; an error
    // action behaves like a failed session build.
    if let Some(fp) = &cfg.fault_plan {
        if let Err(e) = fp.check(FaultSite::WorkerStart) {
            if let Some(r) = ready {
                shared.mark_dead();
                let _ = r.send(Err(anyhow::anyhow!(e)));
            }
            return;
        }
    }
    // the session must be constructed on this thread: PJRT handles are
    // !Send (the mock backend rides the same engine type, so it follows
    // the same rule). Every worker's session evaluates through the one
    // fleet-shared cache handle.
    let session =
        if cfg.use_mock_engine { Ok(Session::mock()) } else { Session::load(&cfg.artifacts_dir) };
    let mut session = match session {
        Ok(s) => s.with_cache(fleet.cache()),
        Err(e) => {
            if let Some(r) = ready {
                shared.mark_dead();
                let _ = r.send(Err(e));
            }
            return;
        }
    };
    session.fault_plan = cfg.fault_plan.clone();
    // engine presence is validated exactly once, here — the loop below
    // never needs the old mid-loop `expect`s, and a missing engine is a
    // typed startup error instead of a serve-time panic
    let Some(gen_batch) = session.engine().map(|e| e.stats.gen_batch) else {
        if let Some(r) = ready {
            shared.mark_dead();
            let _ = r.send(Err(anyhow::Error::new(NoEngineError)));
        }
        return;
    };
    if let Some(r) = ready {
        let _ = r.send(Ok(()));
    }

    // rng streams must never repeat across respawns: each worker draws
    // from its own 2^32-wide block
    let mut stream: u64 = (idx as u64) << 32;
    let mut pending: Vec<PendingGen> = Vec::new();
    loop {
        shared.prune_terminal();
        if shared.stopping() {
            // drain: retire partially-served batcher requests with their
            // partial outcomes (same contract as a cancel)
            for p in pending.drain(..) {
                finish_pending(&registry, &metrics, p, StopReason::Cancelled);
            }
            return;
        }
        // wait for work (or the flush deadline if a batch is forming); a
        // fleet worker keeps the idle wait short so it notices stealable
        // backlog on a sibling's deque promptly
        let timeout = if !pending.is_empty() {
            cfg.batch_window
        } else if fleet.size() > 1 {
            Duration::from_millis(20)
        } else {
            Duration::from_millis(200)
        };
        let msg = match shared.pop(timeout) {
            Some(m) => Some(m),
            // own deque empty: steal from the back of the longest sibling
            // deque (never while draining — queued work then belongs to
            // the victim's own drain path)
            None if !shared.stopping() => fleet.steal(slot, &metrics),
            None => None,
        };

        if let Some(Msg::Run { entry, reply }) = msg {
            let _busy = metrics.busy();
            shared.track(&entry, &reply);
            let work = {
                let sr = &entry.request;
                if sr.optimizer == OptimizerKind::DiffAxE && sr.budget.wall_clock_s.is_none() {
                    match session.engine() {
                        Some(engine) => gen_work(engine, &sr.objective, gen_batch),
                        None => None,
                    }
                } else {
                    None
                }
            };
            if let Some(work) = work {
                // generative work joins the continuous batcher
                if registry.start(&entry) {
                    let p = PendingGen {
                        work,
                        n: entry.request.budget.evals,
                        top_k: entry.request.top_k.unwrap_or(DEFAULT_TOP_K),
                        objective: entry.request.objective,
                        acc: Vec::new(),
                        segs: Vec::new(),
                        bounds: Vec::new(),
                        best: f64::INFINITY,
                        entry: entry.clone(),
                        joined: Instant::now(),
                        reply,
                    };
                    if p.n == 0 {
                        // `Budget::evals(0)` answers immediately with the
                        // empty budget-exhausted outcome — the same
                        // contract every direct-path strategy honors
                        // (`dse::api::drained`) — instead of a forced
                        // minimum generation
                        finish_pending(&registry, &metrics, p, StopReason::BudgetExhausted);
                    } else {
                        pending.push(p);
                    }
                } else if let Some(reply) = reply {
                    // cancelled while queued: deliver the stored result
                    let _ = reply.send(entry.result_now());
                }
            } else {
                // non-batchable jobs flush the batch first (ordering)
                guarded_flush(&session, &registry, &mut pending, cfg.seed, &mut stream, &metrics);
                if registry.start(&entry) {
                    run_job(&mut session, &registry, &entry, reply, cfg.seed, &metrics);
                } else if let Some(reply) = reply {
                    let _ = reply.send(entry.result_now());
                }
            }
        }

        // flush when full or when the window expired with waiters (the
        // window clock starts when a request joins `pending`, not at
        // submission — queue wait behind non-batchable jobs must not
        // expire the window)
        let slots: usize = pending.iter().map(|p| p.slots_remaining()).sum();
        let window_expired = pending
            .iter()
            .map(|p| p.joined.elapsed())
            .max()
            .map(|d| d >= cfg.batch_window)
            .unwrap_or(false);
        if slots >= gen_batch || (window_expired && !pending.is_empty()) {
            let _busy = metrics.busy();
            guarded_flush(&session, &registry, &mut pending, cfg.seed, &mut stream, &metrics);
        }
    }
}

/// [`flush_gen_batch`] under panic isolation: a panic inside the flush
/// (sampler, evaluator, or an injected fault) fails the jobs that were in
/// the batch instead of killing the worker.
fn guarded_flush(
    session: &Session,
    registry: &Arc<JobRegistry>,
    pending: &mut Vec<PendingGen>,
    seed: u64,
    stream: &mut u64,
    metrics: &Arc<Metrics>,
) {
    let caught = catch_unwind(AssertUnwindSafe(|| {
        flush_gen_batch(session, registry, pending, seed, stream, metrics);
    }));
    if let Err(payload) = caught {
        let msg = fault::panic_message(payload.as_ref());
        metrics.record_error();
        for p in pending.drain(..) {
            let resp =
                Response::error(ErrorCode::Internal, format!("batch flush panicked: {msg}"));
            registry.finalize(&p.entry, JobState::Failed, resp.clone());
            if let Some(reply) = p.reply {
                let _ = reply.send(resp);
            }
        }
    }
}

/// Execute one non-batchable job directly on the session, under a ctx
/// carrying the job's cancellation flag and a progress sink into the
/// registry's coalescing event slot. The search itself runs inside
/// `catch_unwind`: a panicking strategy finalizes *this* job as failed
/// (with the panic message) while the worker survives. Finalization and
/// the reply stay outside the isolation barrier — a panic there is a
/// worker-level fault the supervisor handles.
fn run_job(
    session: &mut Session,
    registry: &Arc<JobRegistry>,
    entry: &Arc<JobEntry>,
    reply: Option<Sender<Response>>,
    seed: u64,
    metrics: &Arc<Metrics>,
) {
    // per-job deterministic stream: a crash-retried or stolen job
    // recomputes the identical search no matter which worker (or respawn)
    // runs it. The top bit keeps job streams disjoint from the workers'
    // `idx << 32` sampler stream blocks.
    let job_stream = (1u64 << 63) | entry.num();
    let sr = &entry.request;
    let ctx = {
        let registry = registry.clone();
        let sink_entry = entry.clone();
        SearchCtx::background()
            .with_cancel_flag(entry.cancel_flag())
            .with_progress(move |ev: &SearchEvent| registry.publish(&sink_entry, *ev))
    };
    let searched = catch_unwind(AssertUnwindSafe(|| {
        session.search_ctx(
            sr.optimizer,
            &ctx,
            &sr.objective,
            &sr.budget,
            rng::derive(seed, job_stream),
        )
    }));
    let resp = match searched {
        Ok(Ok(out)) => {
            metrics.record_evaluations(out.evals);
            let cs = session.cache_stats();
            metrics.record_cache(cs.hits, cs.misses);
            Response::Outcome(out.truncated(sr.top_k.unwrap_or(DEFAULT_TOP_K)))
        }
        Ok(Err(e)) => {
            metrics.record_error();
            Response::error(ErrorCode::Internal, format!("{e:#}"))
        }
        Err(payload) => {
            metrics.record_error();
            Response::error(
                ErrorCode::Internal,
                format!("search panicked: {}", fault::panic_message(payload.as_ref())),
            )
        }
    };
    let state = match &resp {
        Response::Outcome(o) if o.stopped == StopReason::Cancelled => JobState::Cancelled,
        Response::Outcome(_) => JobState::Done,
        _ => JobState::Failed,
    };
    let designs = match &resp {
        Response::Outcome(o) => o.ranked.len(),
        _ => 0,
    };
    metrics.record_request(entry.submitted.elapsed().as_secs_f64() * 1e6, designs);
    registry.finalize(entry, state, resp.clone());
    if let Some(reply) = reply {
        let _ = reply.send(resp);
    }
}

/// Retire one batcher request with whatever it accumulated.
fn finish_pending(
    registry: &Arc<JobRegistry>,
    metrics: &Arc<Metrics>,
    p: PendingGen,
    stopped: StopReason,
) {
    let latency_s = p.entry.submitted.elapsed().as_secs_f64();
    metrics.record_request(latency_s * 1e6, p.acc.len());
    // `segs` is empty for non-structured work; for structured work it is
    // parallel to `acc`, so the ranked outcome carries the heterogeneous
    // per-segment configurations alongside the envelope reports. All-empty
    // cut vectors collapse to the canonical fixed partition (no
    // `boundaries` on the wire), keeping pre-learned-segmentation
    // outcomes byte-stable.
    let bounds = if p.bounds.iter().all(|b| b.is_empty()) { Vec::new() } else { p.bounds };
    let outcome = SearchOutcome::from_reports_with_structure(
        "DiffAxE",
        &p.objective,
        p.acc,
        p.segs,
        bounds,
        latency_s,
    )
    .with_stopped(stopped)
    .truncated(p.top_k);
    let state =
        if stopped == StopReason::Cancelled { JobState::Cancelled } else { JobState::Done };
    let resp = Response::Outcome(outcome);
    registry.finalize(&p.entry, state, resp.clone());
    if let Some(reply) = p.reply {
        let _ = reply.send(resp);
    }
}

/// Evaluate one owner's fresh sampler draws under its work kind,
/// accumulate reports (and joint segment vectors for structured work),
/// and return the number of design evaluations performed.
fn score_draws(session: &Session, p: &mut PendingGen, cfgs: &[HwConfig]) -> usize {
    let mut reports: Vec<DesignReport> = Vec::new();
    let mut segs: Vec<Vec<HwConfig>> = Vec::new();
    let mut cand_bounds: Vec<Vec<usize>> = Vec::new();
    match &p.work {
        GenWork::Runtime { g, .. } => {
            // memoized + pooled hot path: recurring rounded designs
            // across requests and tenants become cache hits
            reports = cfgs
                .iter()
                .zip(session.evaluate_batch(cfgs, g))
                .map(|(hw, (s, e))| DesignReport::from_sim(*hw, &s, &e))
                .collect();
        }
        GenWork::Llm { .. } => {
            // whole-model evaluation per candidate, memoized per layer
            // through the shared cache
            reports = p.objective.evaluate_all(cfgs);
        }
        GenWork::Structured { spec, reps, bounds } => {
            // contiguous slot groups form joint candidates: one segment
            // config per slot — already correlated through the shared
            // budget by `sample_joint` — re-constrained (idempotent) and
            // evaluated whole-model under the learned cuts (the envelope
            // report ranks; segment vector + cuts ride along for the
            // outcome)
            for group in cfgs.chunks_exact(reps.len()) {
                let cfg = constrain(&spec.budget, group.to_vec());
                let d = structured::eval_structured_at(spec, &cfg, bounds);
                reports.push(d.report());
                segs.push(d.config.segments);
                cand_bounds.push(bounds.clone());
            }
        }
    }
    let evaluated = reports.len();
    let mut segs = segs.into_iter();
    let mut cand_bounds = cand_bounds.into_iter();
    for d in reports {
        let score = p.objective.score_report(&d);
        p.best = p.best.min(score);
        p.acc.push(d);
        if let Some(sv) = segs.next() {
            p.segs.push(sv);
            p.bounds.push(cand_bounds.next().unwrap_or_default());
        }
    }
    evaluated
}

/// Pack pending generation requests into sampler batches — one diffusion
/// call per conditioning family per round — batch-evaluate the designs,
/// publish per-request progress, and retire each request with a ranked
/// outcome — early (partial) if its cancellation flag is up.
fn flush_gen_batch(
    session: &Session,
    registry: &Arc<JobRegistry>,
    pending: &mut Vec<PendingGen>,
    seed: u64,
    stream: &mut u64,
    metrics: &Arc<Metrics>,
) {
    let Some(engine) = session.engine() else { return };
    let b = engine.stats.gen_batch;
    while !pending.is_empty() {
        // cancelled batcher jobs retire immediately with their partial acc
        for idx in (0..pending.len()).rev() {
            if pending[idx].entry.cancel.load(Ordering::SeqCst) {
                let p = pending.remove(idx);
                finish_pending(registry, metrics, p, StopReason::Cancelled);
            }
        }
        if pending.is_empty() {
            return;
        }
        for family in [Family::Runtime, Family::Class] {
            // pack this family's waiters: whole requests while they fit,
            // oversized ones split across rounds. Structured work never
            // packs here — its joint conditioning needs one `sample_joint`
            // call per request, issued after the shared-call families.
            let mut rt_slots: Vec<(f32, [f32; 3])> = Vec::new();
            let mut class_slots: Vec<(i32, [f32; 3])> = Vec::new();
            let mut owners: Vec<usize> = Vec::new(); // slot -> pending idx
            for (i, p) in pending.iter_mut().enumerate() {
                if p.work.family() != family {
                    continue;
                }
                let avail = b - owners.len();
                if avail == 0 {
                    break;
                }
                let remaining = p.n.saturating_sub(p.acc.len());
                match &mut p.work {
                    GenWork::Runtime { g, p_norm } => {
                        for _ in 0..remaining.min(avail) {
                            rt_slots.push((*p_norm, g.norm_vec()));
                            owners.push(i);
                        }
                    }
                    GenWork::Llm { layers, cursor } => {
                        for _ in 0..remaining.min(avail) {
                            class_slots.push((0, layers[*cursor % layers.len()].norm_vec()));
                            *cursor += 1;
                            owners.push(i);
                        }
                    }
                    // family() filters structured work out of this loop
                    GenWork::Structured { .. } => {}
                }
            }
            if owners.is_empty() {
                // no waiter from this family (or none fit this round)
                continue;
            }
            *stream += 1;
            let t = Instant::now();
            // fault sites: engine sampling before the diffusion call,
            // batch evaluation after it — either failure fails the whole
            // batch through the same path as a real sampler error
            let result = session
                .fault_check(FaultSite::EngineSample)
                .and_then(|()| match family {
                    Family::Runtime => {
                        engine.sample_runtime(rng::derive_u32(seed, *stream), &rt_slots)
                    }
                    Family::Class => engine.sample_class(
                        ClassMode::Edp,
                        rng::derive_u32(seed, *stream),
                        &class_slots,
                    ),
                    Family::Structured => unreachable!("structured work never packs here"),
                })
                .and_then(|configs| session.fault_check(FaultSite::BatchEval).map(|()| configs));
            metrics.record_sampler_call(t.elapsed().as_secs_f64() * 1e6, owners.len(), b);
            match result {
                Ok(configs) => {
                    // group the new designs per owning request so each
                    // group runs through its evaluation path whole;
                    // structured groups stay contiguous by construction
                    // (one sampler call, slots packed owner-by-owner)
                    let mut per_owner: Vec<Vec<HwConfig>> = vec![Vec::new(); pending.len()];
                    for (slot, hw) in configs.into_iter().enumerate() {
                        per_owner[owners[slot]].push(hw);
                    }
                    let mut evaluated = 0;
                    for (idx, cfgs) in per_owner.iter().enumerate() {
                        if cfgs.is_empty() {
                            continue;
                        }
                        evaluated += score_draws(session, &mut pending[idx], cfgs);
                        // heartbeat into the job's coalescing event slot
                        let p = &pending[idx];
                        registry.publish(
                            &p.entry,
                            SearchEvent {
                                evals: p.acc.len(),
                                best_score: p.best,
                                elapsed_s: p.entry.submitted.elapsed().as_secs_f64(),
                            },
                        );
                    }
                    metrics.record_evaluations(evaluated);
                    let cs = session.cache_stats();
                    metrics.record_cache(cs.hits, cs.misses);
                    // retire fully-served requests (from the end, keep
                    // indices valid)
                    for idx in (0..pending.len()).rev() {
                        if pending[idx].acc.len() >= pending[idx].n {
                            let p = pending.remove(idx);
                            finish_pending(registry, metrics, p, StopReason::Completed);
                        }
                    }
                }
                Err(e) => {
                    // blast-radius containment: a failed sampler call
                    // fails only the requests that owned slots in *this*
                    // round's call. Co-pending work from other families —
                    // or from this family but not packed this round —
                    // keeps its accumulated draws and stays queued.
                    metrics.record_error();
                    let mut failed: Vec<usize> = owners.clone();
                    failed.sort_unstable();
                    failed.dedup();
                    for idx in failed.into_iter().rev() {
                        let p = pending.remove(idx);
                        let resp = Response::error(
                            ErrorCode::Internal,
                            format!("sampler failed: {e:#}"),
                        );
                        registry.finalize(&p.entry, JobState::Failed, resp.clone());
                        if let Some(reply) = p.reply {
                            let _ = reply.send(resp);
                        }
                    }
                }
            }
        }
        flush_joint_round(session, engine, registry, pending, seed, stream, metrics, b);
    }
}

/// One batcher round of jointly-conditioned structured sampling: each
/// structured request issues its *own* `sample_joint` call carrying all
/// of its segment conditions plus the shared budget, so every joint
/// candidate's segment draws are correlated through one call — a joint
/// candidate is never assembled across calls, and two structured requests
/// never share a call (their budgets condition differently).
#[allow(clippy::too_many_arguments)] // lint:allow(too_many_arguments) batcher round plumbing mirrors flush_gen_batch
fn flush_joint_round(
    session: &Session,
    engine: &DiffAxE,
    registry: &Arc<JobRegistry>,
    pending: &mut Vec<PendingGen>,
    seed: u64,
    stream: &mut u64,
    metrics: &Arc<Metrics>,
    b: usize,
) {
    let mut i = 0;
    while i < pending.len() {
        let (take, result) = {
            let p = &pending[i];
            let GenWork::Structured { spec, reps, .. } = &p.work else {
                i += 1;
                continue;
            };
            let s = reps.len();
            // `gen_work` guarantees reps.len() <= gen_batch, so at least
            // one joint candidate fits a call — `take` is 0 only when the
            // request is already fully served
            let take = p.n.saturating_sub(p.acc.len()).min(b / s.max(1));
            if take == 0 {
                let p = pending.remove(i);
                finish_pending(registry, metrics, p, StopReason::Completed);
                continue;
            }
            let conds: Vec<(i32, [f32; 3])> = reps.iter().map(|g| (0, g.norm_vec())).collect();
            *stream += 1;
            let t = Instant::now();
            let result = session
                .fault_check(FaultSite::EngineSample)
                .and_then(|()| {
                    engine.sample_joint(
                        ClassMode::Edp,
                        rng::derive_u32(seed, *stream),
                        &spec.budget,
                        &conds,
                        take,
                    )
                })
                .and_then(|groups| session.fault_check(FaultSite::BatchEval).map(|()| groups));
            metrics.record_sampler_call(t.elapsed().as_secs_f64() * 1e6, take * s, b);
            (take, result)
        };
        match result {
            Ok(groups) => {
                debug_assert_eq!(groups.len(), take);
                let flat: Vec<HwConfig> = groups.into_iter().flatten().collect();
                let evaluated = score_draws(session, &mut pending[i], &flat);
                metrics.record_evaluations(evaluated);
                let cs = session.cache_stats();
                metrics.record_cache(cs.hits, cs.misses);
                let p = &pending[i];
                registry.publish(
                    &p.entry,
                    SearchEvent {
                        evals: p.acc.len(),
                        best_score: p.best,
                        elapsed_s: p.entry.submitted.elapsed().as_secs_f64(),
                    },
                );
                if pending[i].acc.len() >= pending[i].n {
                    let p = pending.remove(i);
                    finish_pending(registry, metrics, p, StopReason::Completed);
                } else {
                    i += 1;
                }
            }
            Err(e) => {
                // same containment contract as the shared-call families:
                // only this request owned the failed call's slots
                metrics.record_error();
                let p = pending.remove(i);
                let resp =
                    Response::error(ErrorCode::Internal, format!("sampler failed: {e:#}"));
                registry.finalize(&p.entry, JobState::Failed, resp.clone());
                if let Some(reply) = p.reply {
                    let _ = reply.send(resp);
                }
            }
        }
    }
}

/// Reject detectably-invalid (objective, optimizer) pairings up front —
/// a client error, reported before any budget is spent.
fn validate(sr: &SearchRequest) -> Result<(), String> {
    if sr.optimizer.supports(&sr.objective) {
        Ok(())
    } else {
        Err(format!("optimizer {:?} does not serve this objective", sr.optimizer.name()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::api::Budget;

    fn request() -> SearchRequest {
        SearchRequest::new(
            Objective::MinEdp { g: Gemm::new(8, 8, 8) },
            Budget::evals(4),
            OptimizerKind::RandomSearch,
        )
    }

    fn done_outcome(evals: usize) -> Response {
        Response::Outcome(SearchOutcome {
            evals,
            ..SearchOutcome::empty("random", StopReason::Completed)
        })
    }

    #[test]
    fn registry_lifecycle_and_gauges() {
        let metrics = Arc::new(Metrics::new());
        let reg = JobRegistry::new(metrics.clone());
        let e = reg.submit(request());
        assert_eq!(e.id, "job-1");
        assert_eq!(e.state(), JobState::Queued);
        assert_eq!(metrics.snapshot().jobs_queued, 1);

        assert!(reg.start(&e));
        assert!(!reg.start(&e), "double start must be rejected");
        assert_eq!(e.state(), JobState::Running);
        assert_eq!(e.attempts(), 1);
        reg.publish(&e, SearchEvent { evals: 2, best_score: 1.0, elapsed_s: 0.0 });
        let s = metrics.snapshot();
        assert_eq!((s.jobs_active, s.event_queue_depth), (1, 1));

        reg.finalize(&e, JobState::Done, done_outcome(4));
        // idempotent: a late cancel cannot overwrite the result
        reg.finalize(&e, JobState::Cancelled, done_outcome(0));
        assert_eq!(e.state(), JobState::Done);
        let info = reg.get("job-1").unwrap().info();
        assert_eq!(info.state, JobState::Done);
        assert_eq!(info.evals, 4);
        assert_eq!(info.attempts, 1);
        let s = metrics.snapshot();
        assert_eq!((s.jobs_active, s.event_queue_depth), (0, 0));
        assert_eq!((s.jobs_completed, s.jobs_cancelled), (1, 0));
    }

    #[test]
    fn queued_cancel_is_immediately_terminal() {
        let metrics = Arc::new(Metrics::new());
        let reg = JobRegistry::new(metrics.clone());
        let e = reg.submit(request());
        let info = reg.cancel(&e.id).unwrap();
        assert_eq!(info.state, JobState::Cancelled);
        assert_eq!(info.evals, 0);
        // the engine later refuses to start it
        assert!(!reg.start(&e));
        match e.result_now() {
            Response::Outcome(o) => {
                assert_eq!(o.stopped, StopReason::Cancelled);
                assert!(o.ranked.is_empty());
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(metrics.snapshot().jobs_cancelled, 1);
        assert!(reg.cancel("job-99").is_none());
    }

    #[test]
    fn requeue_returns_a_running_job_to_the_queue() {
        let metrics = Arc::new(Metrics::new());
        let reg = JobRegistry::new(metrics.clone());
        let e = reg.submit(request());
        assert!(!reg.requeue(&e), "queued jobs cannot requeue");
        assert!(reg.start(&e));
        assert!(reg.requeue(&e), "running jobs requeue after a worker crash");
        assert_eq!(e.state(), JobState::Queued);
        assert_eq!(e.attempts(), 1, "the crashed attempt still counts");
        let s = metrics.snapshot();
        assert_eq!((s.jobs_active, s.jobs_queued), (0, 1));
        // the retry runs and finishes normally
        assert!(reg.start(&e));
        assert_eq!(e.attempts(), 2);
        reg.finalize(&e, JobState::Done, done_outcome(4));
        assert!(!reg.requeue(&e), "terminal jobs cannot requeue");
        let s = metrics.snapshot();
        assert_eq!((s.jobs_active, s.jobs_queued, s.jobs_completed), (0, 0, 1));
    }

    #[test]
    fn force_cancel_terminates_any_state() {
        let metrics = Arc::new(Metrics::new());
        let reg = JobRegistry::new(metrics.clone());
        // queued
        let q = reg.submit(request());
        reg.force_cancel(&q);
        assert_eq!(q.state(), JobState::Cancelled);
        // running
        let r = reg.submit(request());
        reg.start(&r);
        reg.force_cancel(&r);
        assert_eq!(r.state(), JobState::Cancelled);
        // already terminal: first finalization wins
        let d = reg.submit(request());
        reg.start(&d);
        reg.finalize(&d, JobState::Done, done_outcome(2));
        reg.force_cancel(&d);
        assert_eq!(d.state(), JobState::Done);
        let s = metrics.snapshot();
        assert_eq!((s.jobs_active, s.jobs_queued), (0, 0));
        assert_eq!((s.jobs_cancelled, s.jobs_completed), (2, 1));
    }

    #[test]
    fn watcher_sees_coalesced_events_then_terminal() {
        let metrics = Arc::new(Metrics::new());
        let reg = JobRegistry::new(metrics);
        let e = reg.submit(request());
        reg.start(&e);
        // two events land before the watcher polls: drop-to-latest keeps
        // only the newer one
        reg.publish(&e, SearchEvent { evals: 1, best_score: 5.0, elapsed_s: 0.1 });
        reg.publish(&e, SearchEvent { evals: 2, best_score: 3.0, elapsed_s: 0.2 });
        let (seq, ev, terminal) = e.next_event(0);
        assert_eq!(ev.unwrap().evals, 2);
        assert!(terminal.is_none());
        reg.finalize(&e, JobState::Done, done_outcome(2));
        let (_seq, ev, terminal) = e.next_event(seq);
        assert!(ev.is_none(), "stale event must not repeat");
        let (state, resp) = terminal.unwrap();
        assert_eq!(state, JobState::Done);
        assert!(matches!(resp, Response::Outcome(_)));
    }

    #[test]
    fn gc_bounds_terminal_retention() {
        let metrics = Arc::new(Metrics::new());
        let reg = JobRegistry::new(metrics);
        for _ in 0..(MAX_RETAINED_JOBS + 10) {
            let e = reg.submit(request());
            reg.start(&e);
            reg.finalize(&e, JobState::Done, done_outcome(1));
        }
        let jobs = reg.list();
        assert!(jobs.len() <= MAX_RETAINED_JOBS + 1, "retained {}", jobs.len());
        // the oldest jobs were collected, the newest survive
        assert!(reg.get("job-1").is_none());
        assert!(reg.get(&format!("job-{}", MAX_RETAINED_JOBS + 10)).is_some());
    }
}
