//! The DSE service: a dedicated engine thread owning the PJRT executables
//! (they hold raw C pointers and are deliberately never shared), fed by a
//! cloneable handle over an mpsc channel.
//!
//! Runtime-generation requests are **dynamically batched**: the engine
//! thread drains the queue up to the sampler's fixed batch width (slots can
//! mix workloads — the sampler conditions per batch element) before issuing
//! one diffusion call, then splits, evaluates, and replies per request.
//! This is the vLLM-router-style continuous batching adapted to design
//! generation: the expensive fixed-batch executable always runs as full as
//! the queue allows.

use super::metrics::Metrics;
use super::protocol::{DesignReport, Request, Response};
use crate::dse;
use crate::models::DiffAxE;
use crate::workload::Gemm;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// how long the batcher waits to fill a sampler batch
    pub batch_window: Duration,
    pub seed: u32,
}

impl ServiceConfig {
    pub fn new(artifacts_dir: impl Into<std::path::PathBuf>) -> Self {
        ServiceConfig {
            artifacts_dir: artifacts_dir.into(),
            batch_window: Duration::from_millis(4),
            seed: 1,
        }
    }
}

struct Job {
    request: Request,
    reply: Sender<Response>,
    submitted: Instant,
}

/// Cloneable handle to the service.
#[derive(Clone)]
pub struct Handle {
    tx: Sender<Job>,
    metrics: Arc<Metrics>,
}

impl Handle {
    /// Submit a request and block for the response.
    pub fn request(&self, request: Request) -> Response {
        let (reply_tx, reply_rx) = channel();
        let job = Job { request, reply: reply_tx, submitted: Instant::now() };
        if self.tx.send(job).is_err() {
            return Response::Error("service stopped".into());
        }
        reply_rx
            .recv()
            .unwrap_or_else(|_| Response::Error("service dropped request".into()))
    }

    /// Submit without waiting; the receiver yields the response.
    pub fn submit(&self, request: Request) -> Receiver<Response> {
        let (reply_tx, reply_rx) = channel();
        let job = Job { request, reply: reply_tx, submitted: Instant::now() };
        let _ = self.tx.send(job);
        reply_rx
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }
}

/// Running service (engine thread + handle).
pub struct Service {
    pub handle: Handle,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Service {
    /// Start the engine thread. Blocks until the artifacts are compiled (or
    /// fail to), so a returned `Service` is ready to serve.
    pub fn start(cfg: ServiceConfig) -> Result<Service> {
        let (tx, rx) = channel::<Job>();
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let thread = {
            let metrics = metrics.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("diffaxe-engine".into())
                .spawn(move || {
                    // the engine must be constructed on this thread: PJRT
                    // handles are !Send
                    let engine = match DiffAxE::load(&cfg.artifacts_dir) {
                        Ok(e) => {
                            let _ = ready_tx.send(Ok(()));
                            e
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    engine_loop(engine, cfg, rx, metrics, stop);
                })?
        };
        ready_rx.recv()??;
        Ok(Service { handle: Handle { tx, metrics }, stop, thread: Some(thread) })
    }

    pub fn handle(&self) -> Handle {
        self.handle.clone()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the engine thread's recv by dropping our sender clone…
        let (tx, _) = channel();
        let old = std::mem::replace(&mut self.handle.tx, tx);
        drop(old);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// A runtime-generation request waiting in the batcher. `acc` collects
/// designs across sampler calls when the request spans batches.
struct PendingGen {
    g: Gemm,
    p_norm: f32,
    n: usize,
    acc: Vec<DesignReport>,
    reply: Sender<Response>,
    submitted: Instant,
}

fn engine_loop(
    engine: DiffAxE,
    cfg: ServiceConfig,
    rx: Receiver<Job>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    let mut seed = cfg.seed;
    let mut pending: Vec<PendingGen> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        // wait for work (or flush deadline if a batch is forming)
        let job = if pending.is_empty() {
            match rx.recv_timeout(Duration::from_millis(200)) {
                Ok(j) => Some(j),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => return,
            }
        } else {
            match rx.recv_timeout(cfg.batch_window) {
                Ok(j) => Some(j),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => {
                    flush_gen_batch(&engine, &mut pending, &mut seed, &metrics);
                    return;
                }
            }
        };

        if let Some(job) = job {
            match job.request {
                Request::GenerateRuntime { g, target_cycles, n } => {
                    let st = engine.stats.stats_for(&g);
                    pending.push(PendingGen {
                        g,
                        p_norm: st.norm_runtime(target_cycles),
                        n: n.max(1),
                        acc: Vec::new(),
                        reply: job.reply,
                        submitted: job.submitted,
                    });
                }
                other => {
                    // non-batchable requests flush the batch first (ordering)
                    flush_gen_batch(&engine, &mut pending, &mut seed, &metrics);
                    let resp = handle_direct(&engine, &other, &mut seed, &metrics);
                    metrics.record_request(
                        job.submitted.elapsed().as_secs_f64() * 1e6,
                        match &resp {
                            Response::Designs(d) => d.len(),
                            _ => 0,
                        },
                    );
                    let _ = job.reply.send(resp);
                }
            }
        }

        // flush when full or when the window expired with waiters
        let slots: usize = pending.iter().map(|p| p.n).sum();
        let window_expired = pending
            .iter()
            .map(|p| p.submitted.elapsed())
            .max()
            .map(|d| d >= cfg.batch_window)
            .unwrap_or(false);
        if slots >= engine.stats.gen_batch || (window_expired && !pending.is_empty()) {
            flush_gen_batch(&engine, &mut pending, &mut seed, &metrics);
        }
    }
}

/// Pack pending generation requests into sampler batches and reply.
fn flush_gen_batch(
    engine: &DiffAxE,
    pending: &mut Vec<PendingGen>,
    seed: &mut u32,
    metrics: &Arc<Metrics>,
) {
    while !pending.is_empty() {
        let b = engine.stats.gen_batch;
        // take whole requests while they fit; split oversized ones
        let mut slots: Vec<(f32, [f32; 3])> = Vec::with_capacity(b);
        let mut owners: Vec<usize> = Vec::with_capacity(b); // slot -> pending idx
        for (i, p) in pending.iter().enumerate() {
            let take = p.n.saturating_sub(p.acc.len()).min(b - slots.len());
            for _ in 0..take {
                slots.push((p.p_norm, p.g.norm_vec()));
                owners.push(i);
            }
            if slots.len() == b {
                break;
            }
        }
        *seed = seed.wrapping_add(1);
        let t = Instant::now();
        let result = engine.sample_runtime(*seed, &slots);
        metrics.record_sampler_call(t.elapsed().as_secs_f64() * 1e6, slots.len(), b);
        match result {
            Ok(configs) => {
                let mut evaluated = 0;
                for (slot, hw) in configs.into_iter().enumerate() {
                    let idx = owners[slot];
                    let g = pending[idx].g;
                    let (s, e) = dse::evaluate(&hw, &g);
                    evaluated += 1;
                    pending[idx].acc.push(DesignReport {
                        hw,
                        cycles: s.cycles as f64,
                        power_w: e.power_w,
                        edp: e.edp,
                    });
                }
                metrics.record_evaluations(evaluated);
                // retire fully-served requests (from the end, keep indices valid)
                for idx in (0..pending.len()).rev() {
                    if pending[idx].acc.len() >= pending[idx].n {
                        let p = pending.remove(idx);
                        metrics.record_request(
                            p.submitted.elapsed().as_secs_f64() * 1e6,
                            p.acc.len(),
                        );
                        let _ = p.reply.send(Response::Designs(p.acc));
                    }
                }
            }
            Err(e) => {
                metrics.record_error();
                for p in pending.drain(..) {
                    let _ = p.reply.send(Response::Error(format!("sampler failed: {e:#}")));
                }
            }
        }
    }
}

fn handle_direct(
    engine: &DiffAxE,
    req: &Request,
    seed: &mut u32,
    metrics: &Arc<Metrics>,
) -> Response {
    *seed = seed.wrapping_add(1);
    let run = || -> Result<Response> {
        match req {
            Request::EdpSearch { g, n_per_class } => {
                let out = dse::edp::diffaxe_edp(engine, g, *n_per_class, *seed)?;
                let (s, e) = dse::evaluate(&out.best_hw, g);
                Ok(Response::Designs(vec![DesignReport {
                    hw: out.best_hw,
                    cycles: s.cycles as f64,
                    power_w: e.power_w,
                    edp: e.edp,
                }]))
            }
            Request::PerfSearch { g, n } => {
                let out = dse::perfopt::diffaxe_perfopt(engine, g, *n, *seed)?;
                let (s, e) = dse::evaluate(&out.best_hw, g);
                Ok(Response::Designs(vec![DesignReport {
                    hw: out.best_hw,
                    cycles: s.cycles as f64,
                    power_w: e.power_w,
                    edp: e.edp,
                }]))
            }
            Request::LlmSearch { model, stage, n_per_layer } => {
                let (best, _t) = dse::llm::diffaxe_llm(
                    engine,
                    *model,
                    *stage,
                    crate::workload::llm::DEFAULT_SEQ,
                    *n_per_layer,
                    dse::llm::Platform::Asic32nm,
                    *seed,
                )?;
                Ok(Response::Designs(vec![DesignReport {
                    hw: best.cfg.base,
                    cycles: best.sim.cycles as f64,
                    power_w: best.energy.power_w,
                    edp: best.energy.edp,
                }]))
            }
            Request::Metrics => Ok(Response::MetricsText(metrics.snapshot().to_string())),
            Request::GenerateRuntime { .. } => unreachable!("batched upstream"),
        }
    };
    match run() {
        Ok(r) => r,
        Err(e) => {
            metrics.record_error();
            Response::Error(format!("{e:#}"))
        }
    }
}
