//! The DSE service: a dedicated engine thread owning a [`Session`] (the
//! PJRT executables hold raw C pointers and are deliberately never shared),
//! fed by a cloneable handle over an mpsc channel.
//!
//! Runtime-generation searches with the `diffaxe` optimizer are
//! **dynamically batched**: the engine thread drains the queue up to the
//! sampler's fixed batch width (slots can mix workloads — the sampler
//! conditions per batch element) before issuing one diffusion call, then
//! splits, batch-evaluates, and replies per request. This is the
//! vLLM-router-style continuous batching adapted to design generation: the
//! expensive fixed-batch executable always runs as full as the queue
//! allows. Every other `(objective, optimizer)` pair — and whole `Batch`
//! requests — run directly on the session between sampler flushes.
//!
//! Candidate evaluation goes through the session's memoized, pooled hot
//! path ([`crate::dse::eval`]): recurring rounded design points across
//! requests are served from the sharded eval cache, whose hit/miss counters
//! are mirrored into [`Metrics`] after every evaluation burst.

use super::metrics::Metrics;
use super::protocol::{ErrorCode, Request, Response, SearchRequest};
use crate::dse::api::{DesignReport, Objective, OptimizerKind, SearchOutcome, Session};
use crate::design_space::HwConfig;
use crate::util::rng;
use crate::workload::Gemm;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Default cap on ranked designs carried in one response (requests can
/// override with `top_k`).
pub const DEFAULT_TOP_K: usize = 64;

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// how long the batcher waits to fill a sampler batch
    pub batch_window: Duration,
    /// root seed; per-sampler-call and per-search seeds derive from it via
    /// [`rng::derive`]
    pub seed: u64,
}

impl ServiceConfig {
    pub fn new(artifacts_dir: impl Into<std::path::PathBuf>) -> Self {
        ServiceConfig {
            artifacts_dir: artifacts_dir.into(),
            batch_window: Duration::from_millis(4),
            seed: 1,
        }
    }
}

struct Job {
    request: Request,
    reply: Sender<Response>,
    submitted: Instant,
}

/// Cloneable handle to the service.
#[derive(Clone)]
pub struct Handle {
    tx: Sender<Job>,
    metrics: Arc<Metrics>,
}

impl Handle {
    /// Submit a request and block for the response.
    pub fn request(&self, request: Request) -> Response {
        let (reply_tx, reply_rx) = channel();
        let job = Job { request, reply: reply_tx, submitted: Instant::now() };
        if self.tx.send(job).is_err() {
            return Response::error(ErrorCode::Internal, "service stopped");
        }
        reply_rx
            .recv()
            .unwrap_or_else(|_| Response::error(ErrorCode::Internal, "service dropped request"))
    }

    /// Submit without waiting; the receiver yields the response.
    pub fn submit(&self, request: Request) -> Receiver<Response> {
        let (reply_tx, reply_rx) = channel();
        let job = Job { request, reply: reply_tx, submitted: Instant::now() };
        let _ = self.tx.send(job);
        reply_rx
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }
}

/// Running service (engine thread + handle).
pub struct Service {
    pub handle: Handle,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Service {
    /// Start the engine thread. Blocks until the artifacts are compiled (or
    /// fail to), so a returned `Service` is ready to serve.
    pub fn start(cfg: ServiceConfig) -> Result<Service> {
        let (tx, rx) = channel::<Job>();
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let thread = {
            let metrics = metrics.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("diffaxe-engine".into())
                .spawn(move || {
                    // the session must be constructed on this thread: PJRT
                    // handles are !Send
                    let session = match Session::load(&cfg.artifacts_dir) {
                        Ok(s) => {
                            let _ = ready_tx.send(Ok(()));
                            s
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    engine_loop(session, cfg, rx, metrics, stop);
                })?
        };
        ready_rx.recv()??;
        Ok(Service { handle: Handle { tx, metrics }, stop, thread: Some(thread) })
    }

    pub fn handle(&self) -> Handle {
        self.handle.clone()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the engine thread's recv by dropping our sender clone…
        let (tx, _) = channel();
        let old = std::mem::replace(&mut self.handle.tx, tx);
        drop(old);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// A runtime-generation search waiting in the batcher. `acc` collects
/// designs across sampler calls when the request spans batches.
struct PendingGen {
    g: Gemm,
    p_norm: f32,
    n: usize,
    top_k: usize,
    objective: Objective,
    acc: Vec<DesignReport>,
    reply: Sender<Response>,
    submitted: Instant,
}

fn engine_loop(
    mut session: Session,
    cfg: ServiceConfig,
    rx: Receiver<Job>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    let gen_batch = session.engine().expect("service session has an engine").stats.gen_batch;
    let mut stream = 0u64;
    let mut pending: Vec<PendingGen> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        // wait for work (or flush deadline if a batch is forming)
        let job = if pending.is_empty() {
            match rx.recv_timeout(Duration::from_millis(200)) {
                Ok(j) => Some(j),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => return,
            }
        } else {
            match rx.recv_timeout(cfg.batch_window) {
                Ok(j) => Some(j),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => {
                    flush_gen_batch(&session, &mut pending, cfg.seed, &mut stream, &metrics);
                    return;
                }
            }
        };

        if let Some(job) = job {
            match job.request {
                // runtime-conditioned diffusion joins the continuous batcher
                // (wall-clock-capped requests go through the direct path,
                // which honours Budget::wall_clock_s)
                Request::Search(sr)
                    if sr.optimizer == OptimizerKind::DiffAxE
                        && matches!(sr.objective, Objective::Runtime { .. })
                        && sr.budget.wall_clock_s.is_none() =>
                {
                    let Objective::Runtime { g, target_cycles } = sr.objective else {
                        unreachable!("guard matched Runtime")
                    };
                    let engine = session.engine().expect("engine");
                    pending.push(PendingGen {
                        g,
                        p_norm: engine.stats.stats_for(&g).norm_runtime(target_cycles),
                        n: sr.budget.evals.max(1),
                        top_k: sr.top_k.unwrap_or(DEFAULT_TOP_K),
                        objective: sr.objective,
                        acc: Vec::new(),
                        reply: job.reply,
                        submitted: job.submitted,
                    });
                }
                other => {
                    // non-batchable requests flush the batch first (ordering)
                    flush_gen_batch(&session, &mut pending, cfg.seed, &mut stream, &metrics);
                    let resp =
                        handle_direct(&mut session, &other, cfg.seed, &mut stream, &metrics);
                    metrics.record_request(
                        job.submitted.elapsed().as_secs_f64() * 1e6,
                        match &resp {
                            Response::Outcome(o) => o.ranked.len(),
                            Response::Batch(outs) => outs.iter().map(|o| o.ranked.len()).sum(),
                            _ => 0,
                        },
                    );
                    let _ = job.reply.send(resp);
                }
            }
        }

        // flush when full or when the window expired with waiters
        let slots: usize = pending.iter().map(|p| p.n.saturating_sub(p.acc.len())).sum();
        let window_expired = pending
            .iter()
            .map(|p| p.submitted.elapsed())
            .max()
            .map(|d| d >= cfg.batch_window)
            .unwrap_or(false);
        if slots >= gen_batch || (window_expired && !pending.is_empty()) {
            flush_gen_batch(&session, &mut pending, cfg.seed, &mut stream, &metrics);
        }
    }
}

/// Pack pending generation requests into sampler batches, batch-evaluate
/// the designs, and reply with ranked outcomes.
fn flush_gen_batch(
    session: &Session,
    pending: &mut Vec<PendingGen>,
    seed: u64,
    stream: &mut u64,
    metrics: &Arc<Metrics>,
) {
    let Some(engine) = session.engine() else { return };
    while !pending.is_empty() {
        let b = engine.stats.gen_batch;
        // take whole requests while they fit; split oversized ones
        let mut slots: Vec<(f32, [f32; 3])> = Vec::with_capacity(b);
        let mut owners: Vec<usize> = Vec::with_capacity(b); // slot -> pending idx
        for (i, p) in pending.iter().enumerate() {
            let take = p.n.saturating_sub(p.acc.len()).min(b - slots.len());
            for _ in 0..take {
                slots.push((p.p_norm, p.g.norm_vec()));
                owners.push(i);
            }
            if slots.len() == b {
                break;
            }
        }
        *stream += 1;
        let t = Instant::now();
        let result = engine.sample_runtime(rng::derive_u32(seed, *stream), &slots);
        metrics.record_sampler_call(t.elapsed().as_secs_f64() * 1e6, slots.len(), b);
        match result {
            Ok(configs) => {
                // group the new designs per owning request so each group
                // runs through the vectorized evaluation hot path
                let mut per_owner: Vec<Vec<HwConfig>> = vec![Vec::new(); pending.len()];
                for (slot, hw) in configs.into_iter().enumerate() {
                    per_owner[owners[slot]].push(hw);
                }
                let mut evaluated = 0;
                for (idx, cfgs) in per_owner.iter().enumerate() {
                    if cfgs.is_empty() {
                        continue;
                    }
                    let g = pending[idx].g;
                    // memoized + pooled hot path: recurring rounded designs
                    // across requests become cache hits
                    for (hw, (s, e)) in cfgs.iter().zip(session.evaluate_batch(cfgs, &g)) {
                        pending[idx].acc.push(DesignReport::from_sim(*hw, &s, &e));
                    }
                    evaluated += cfgs.len();
                }
                metrics.record_evaluations(evaluated);
                let cs = session.cache_stats();
                metrics.record_cache(cs.hits, cs.misses);
                // retire fully-served requests (from the end, keep indices valid)
                for idx in (0..pending.len()).rev() {
                    if pending[idx].acc.len() >= pending[idx].n {
                        let p = pending.remove(idx);
                        let latency_s = p.submitted.elapsed().as_secs_f64();
                        metrics.record_request(latency_s * 1e6, p.acc.len());
                        let outcome = SearchOutcome::from_reports(
                            "DiffAxE",
                            &p.objective,
                            p.acc,
                            latency_s,
                        )
                        .truncated(p.top_k);
                        let _ = p.reply.send(Response::Outcome(outcome));
                    }
                }
            }
            Err(e) => {
                metrics.record_error();
                for p in pending.drain(..) {
                    let _ = p.reply.send(Response::error(
                        ErrorCode::Internal,
                        format!("sampler failed: {e:#}"),
                    ));
                }
            }
        }
    }
}

/// Run one search on the session with a derived per-request seed.
fn run_search(
    session: &mut Session,
    sr: &SearchRequest,
    seed: u64,
    stream: &mut u64,
) -> Result<SearchOutcome> {
    *stream += 1;
    let out = session.search(sr.optimizer, &sr.objective, &sr.budget, rng::derive(seed, *stream))?;
    Ok(out.truncated(sr.top_k.unwrap_or(DEFAULT_TOP_K)))
}

/// Reject detectably-invalid (objective, optimizer) pairings up front —
/// a client error, reported before any budget is spent.
fn validate(sr: &SearchRequest) -> Result<(), String> {
    if sr.optimizer.supports(&sr.objective) {
        Ok(())
    } else {
        Err(format!("optimizer {:?} does not serve this objective", sr.optimizer.name()))
    }
}

fn handle_direct(
    session: &mut Session,
    req: &Request,
    seed: u64,
    stream: &mut u64,
    metrics: &Arc<Metrics>,
) -> Response {
    match req {
        Request::Metrics => Response::MetricsText(metrics.snapshot().to_string()),
        Request::Search(sr) => {
            if let Err(msg) = validate(sr) {
                return Response::error(ErrorCode::BadRequest, msg);
            }
            match run_search(session, sr, seed, stream) {
                Ok(out) => {
                    metrics.record_evaluations(out.evals);
                    let cs = session.cache_stats();
                    metrics.record_cache(cs.hits, cs.misses);
                    Response::Outcome(out)
                }
                Err(e) => {
                    metrics.record_error();
                    Response::error(ErrorCode::Internal, format!("{e:#}"))
                }
            }
        }
        Request::Batch(items) => {
            // validate the whole batch before running any item, so a bad
            // pairing cannot discard minutes of completed sibling searches
            for (i, sr) in items.iter().enumerate() {
                if let Err(msg) = validate(sr) {
                    return Response::error(ErrorCode::BadRequest, format!("batch item {i}: {msg}"));
                }
            }
            let mut outs = Vec::with_capacity(items.len());
            for (i, sr) in items.iter().enumerate() {
                match run_search(session, sr, seed, stream) {
                    Ok(out) => {
                        metrics.record_evaluations(out.evals);
                        let cs = session.cache_stats();
                        metrics.record_cache(cs.hits, cs.misses);
                        outs.push(out);
                    }
                    Err(e) => {
                        // all-or-nothing by protocol contract (see the
                        // `batch` docs in protocol.rs)
                        metrics.record_error();
                        return Response::error(
                            ErrorCode::Internal,
                            format!("batch item {i} ({}): {e:#}", sr.optimizer.name()),
                        );
                    }
                }
            }
            Response::Batch(outs)
        }
    }
}
