//! Supervision for one engine-worker slot: a bounded dispatch deque with
//! admission control, a panic-isolated worker restarted under bounded
//! exponential backoff, in-flight job recovery (retry or terminal
//! failure), and a deadline-bounded graceful drain.
//!
//! # Supervision tree
//!
//! ```text
//! Service::start
//!   └── Fleet                          (coordinator/fleet.rs)
//!         ├── diffaxe-supervisor-0     (this module, one per slot)
//!         │     └── diffaxe-engine-{n} (n = fleet-wide spawn index)
//!         │           owns the Session — PJRT handles are !Send
//!         ├── diffaxe-supervisor-1
//!         │     └── diffaxe-engine-{m}
//!         └── …                        (ServiceConfig::workers slots)
//! ```
//!
//! Each supervisor spawns its slot's worker, parks on its death channel,
//! and on an unexpected death (a panic that escaped the worker's own
//! `catch_unwind` isolation, or a plain exit) reaps the panic payload,
//! recovers every in-flight job — requeued at the *front* of the slot's
//! deque when the job's attempt budget allows, terminally failed
//! otherwise — and respawns the worker with exponential backoff. After
//! `max_worker_restarts` respawns the supervisor gives up: it marks its
//! *slot* dead and fails everything still queued on it; the fleet keeps
//! dispatching to the surviving slots, so a crashed worker degrades
//! capacity, not availability. Admission rejects only when every slot is
//! dead. Restart budgets are per slot.
//!
//! Every slot's deque draws from one fleet-wide [`QueueBudget`] so the
//! global admission bound (`ServiceConfig::max_queued`) is preserved no
//! matter how dispatch spreads jobs; crash-recovery requeues bypass the
//! budget check (`force_acquire`) so a recovered job is never shed.
//!
//! # Drain ordering
//!
//! `Shared::begin_stop` closes admissions; the supervisor then (1)
//! terminally cancels everything still queued, (2) raises the cancel flag
//! on every in-flight job, (3) waits up to the drain deadline for the
//! worker to finish, and (4) force-cancels whatever is left so **every**
//! watcher and synchronous waiter wakes. Finalization is idempotent
//! first-wins, so a detached worker finishing late cannot regress a
//! terminal state. See `docs/INVARIANTS.md` ("Drain ordering").

use super::fleet::Fleet;
use super::metrics::Metrics;
use super::protocol::{ErrorCode, JobState, Response};
use super::service::{worker_main, JobEntry, JobRegistry, ServiceConfig};
use crate::util::fault;
use crate::util::sync::{rank, TrackedMutex};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Typed startup error: the session built, but carries no generative
/// engine — `serve` needs DiffAxE artifacts (`--artifacts`) or the mock
/// engine (`--mock`). Surfaced from `Service::start` instead of the old
/// mid-loop `expect` panics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NoEngineError;

impl std::fmt::Display for NoEngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(
            "session has no generative engine; serve requires DiffAxE artifacts \
             (--artifacts DIR) or the mock engine",
        )
    }
}

impl std::error::Error for NoEngineError {}

/// One unit of worker work: run a registered job, optionally delivering
/// the terminal response to a synchronous waiter.
pub(crate) enum Msg {
    Run { entry: Arc<JobEntry>, reply: Option<Sender<Response>> },
}

/// An in-flight job the worker has popped but not yet finalized. `reply`
/// is a *clone* of the synchronous waiter's sender: if the worker dies
/// mid-job the supervisor can still deliver a terminal response.
struct Inflight {
    entry: Arc<JobEntry>,
    reply: Option<Sender<Response>>,
}

/// Fleet-wide admission budget: every worker slot's deque draws queued
/// capacity from this one counter, so `ServiceConfig::max_queued` bounds
/// the *total* queued work no matter how dispatch spreads it across
/// slots. Crash recovery re-acquires unconditionally (`force_acquire`):
/// a job that was already admitted is never shed on requeue.
pub(crate) struct QueueBudget {
    queued: AtomicUsize,
    max: usize,
}

impl QueueBudget {
    pub(crate) fn new(max: usize) -> Arc<QueueBudget> {
        Arc::new(QueueBudget { queued: AtomicUsize::new(0), max: max.max(1) })
    }

    fn try_acquire(&self) -> bool {
        self.queued
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| (n < self.max).then_some(n + 1))
            .is_ok()
    }

    fn force_acquire(&self) {
        self.queued.fetch_add(1, Ordering::SeqCst);
    }

    fn release(&self) {
        let _ = self
            .queued
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| Some(n.saturating_sub(1)));
    }

    pub(crate) fn max(&self) -> usize {
        self.max
    }
}

/// State shared between the handle (admission), the worker (dispatch),
/// and the supervisor (recovery + drain). One `Shared` per fleet slot.
pub(crate) struct Shared {
    queue: TrackedMutex<VecDeque<Msg>>,
    queue_cv: Condvar,
    inflight: TrackedMutex<Vec<Inflight>>,
    /// drain started: admissions closed, worker exits at its loop top
    stop: AtomicBool,
    /// restart budget exhausted (or startup validation failed): the
    /// service permanently rejects new work
    dead: AtomicBool,
    max_queued: usize,
    /// fleet-wide queued-capacity counter this slot's deque draws from
    budget: Arc<QueueBudget>,
    drain_deadline_ms: AtomicU64,
}

impl Shared {
    /// A standalone slot whose deque bound *is* the global bound (the
    /// single-worker shape, and what the unit tests drive directly).
    pub(crate) fn new(max_queued: usize, drain_deadline: Duration) -> Shared {
        Shared::with_budget(max_queued, drain_deadline, QueueBudget::new(max_queued))
    }

    /// A fleet slot: a deque additionally capped at `max_queued` whose
    /// admissions draw from the shared fleet-wide `budget`.
    pub(crate) fn with_budget(
        max_queued: usize,
        drain_deadline: Duration,
        budget: Arc<QueueBudget>,
    ) -> Shared {
        Shared {
            queue: TrackedMutex::new(
                "supervisor.queue",
                rank::SUPERVISOR_QUEUE,
                VecDeque::new(),
            ),
            queue_cv: Condvar::new(),
            inflight: TrackedMutex::new("supervisor.inflight", rank::SUPERVISOR_INFLIGHT, Vec::new()),
            stop: AtomicBool::new(false),
            dead: AtomicBool::new(false),
            max_queued: max_queued.max(1),
            budget,
            drain_deadline_ms: AtomicU64::new(drain_deadline.as_millis() as u64),
        }
    }

    /// Admission control: atomically depth-check, register (via `submit`,
    /// which runs under the queue lock — ranks `SUPERVISOR_QUEUE` <
    /// `REGISTRY` make that legal), and enqueue a job. Draining, dead, and
    /// over-capacity services reject with a structured error instead; the
    /// overload rejection carries a `retry_after_ms` hint and counts into
    /// `jobs_shed`.
    pub(crate) fn admit(
        &self,
        metrics: &Metrics,
        submit: impl FnOnce() -> Arc<JobEntry>,
        reply: Option<Sender<Response>>,
    ) -> Result<Arc<JobEntry>, Response> {
        let mut q = self.queue.lock();
        if self.is_dead() {
            return Err(Response::error(
                ErrorCode::Internal,
                "engine worker unavailable (restart budget exhausted)",
            ));
        }
        if self.stopping() {
            return Err(Response::error(
                ErrorCode::Overloaded,
                "service draining; admissions closed",
            ));
        }
        // per-slot depth first (short-circuits so the global budget is
        // only drawn when this deque has room), then the fleet-wide bound
        if q.len() >= self.max_queued || !self.budget.try_acquire() {
            drop(q);
            metrics.job_shed();
            // a full queue of short jobs drains fast; scale the hint with
            // the configured depth and cap it at something polite
            let bound = self.max_queued.min(self.budget.max());
            let retry_after_ms = (50 + 10 * bound as u64).min(5_000);
            return Err(Response::overloaded(
                format!("queue full: {bound} jobs queued (max {bound})"),
                retry_after_ms,
            ));
        }
        let entry = submit();
        q.push_back(Msg::Run { entry: entry.clone(), reply });
        self.queue_cv.notify_one();
        Ok(entry)
    }

    /// Worker-side dispatch: the next queued message, or `None` on
    /// timeout, spurious wakeup, or stop (callers re-check `stopping`).
    pub(crate) fn pop(&self, timeout: Duration) -> Option<Msg> {
        let mut q = self.queue.lock();
        if self.stopping() {
            return None;
        }
        if q.is_empty() {
            let (g, _timed_out) = q.wait_timeout(&self.queue_cv, timeout);
            q = g;
        }
        if self.stopping() {
            None
        } else {
            let msg = q.pop_front();
            if msg.is_some() {
                self.budget.release();
            }
            msg
        }
    }

    /// Thief-side dispatch: pop from the *back* of this slot's deque —
    /// the opposite end from `pop`, so the victim worker and a stealing
    /// sibling never contend for the same message (the dispatch/steal
    /// ordering invariant; see `docs/INVARIANTS.md`).
    pub(crate) fn steal_back(&self) -> Option<Msg> {
        if self.stopping() || self.is_dead() {
            return None;
        }
        let msg = self.queue.lock().pop_back();
        if msg.is_some() {
            self.budget.release();
        }
        msg
    }

    /// Current deque depth (least-loaded dispatch / longest-queue steal).
    pub(crate) fn queue_len(&self) -> usize {
        self.queue.lock().len()
    }

    /// Put a crash-recovered job at the *front* of the queue: it already
    /// waited its turn once. Re-acquires the global budget unconditionally
    /// — an admitted job is never shed on recovery.
    fn requeue_front(&self, msg: Msg) {
        self.budget.force_acquire();
        self.queue.lock().push_front(msg);
        self.queue_cv.notify_one();
    }

    fn drain_queue(&self) -> Vec<Msg> {
        let msgs: Vec<Msg> = self.queue.lock().drain(..).collect();
        for _ in &msgs {
            self.budget.release();
        }
        msgs
    }

    /// Record a popped job as in-flight (crash recovery roster).
    pub(crate) fn track(&self, entry: &Arc<JobEntry>, reply: &Option<Sender<Response>>) {
        self.inflight.lock().push(Inflight { entry: entry.clone(), reply: reply.clone() });
    }

    /// Drop finalized jobs from the in-flight roster. Takes the roster
    /// lock, then each entry's core one at a time — ranks
    /// `SUPERVISOR_INFLIGHT` < `JOB_CORE` strictly increase.
    pub(crate) fn prune_terminal(&self) {
        self.inflight.lock().retain(|i| !i.entry.state().terminal());
    }

    fn take_inflight(&self) -> Vec<Inflight> {
        std::mem::take(&mut *self.inflight.lock())
    }

    fn cancel_inflight(&self) {
        for inf in self.inflight.lock().iter() {
            inf.entry.cancel_flag().store(true, Ordering::SeqCst);
        }
    }

    /// Close admissions and wake the worker so the drain can begin.
    pub(crate) fn begin_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.queue_cv.notify_all();
    }

    pub(crate) fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    pub(crate) fn mark_dead(&self) {
        self.dead.store(true, Ordering::SeqCst);
    }

    pub(crate) fn is_dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    pub(crate) fn set_drain_deadline(&self, d: Duration) {
        self.drain_deadline_ms.store(d.as_millis() as u64, Ordering::SeqCst);
    }

    fn drain_deadline(&self) -> Duration {
        Duration::from_millis(self.drain_deadline_ms.load(Ordering::SeqCst))
    }
}

/// Spawn the supervisor thread for one fleet slot. `ready` reports the
/// slot's first worker's startup result (session build + engine
/// validation) back to `Service::start`.
pub(crate) fn spawn(
    cfg: ServiceConfig,
    fleet: Arc<Fleet>,
    slot: usize,
    registry: Arc<JobRegistry>,
    metrics: Arc<Metrics>,
    ready: Sender<anyhow::Result<()>>,
) -> std::io::Result<JoinHandle<()>> {
    std::thread::Builder::new()
        .name(format!("diffaxe-supervisor-{slot}"))
        .spawn(move || supervise(cfg, fleet, slot, registry, metrics, ready))
}

fn supervise(
    cfg: ServiceConfig,
    fleet: Arc<Fleet>,
    slot: usize,
    registry: Arc<JobRegistry>,
    metrics: Arc<Metrics>,
    ready: Sender<anyhow::Result<()>>,
) {
    let shared = fleet.slot(slot).clone();
    let mut ready = Some(ready);
    let mut restarts: u32 = 0;
    loop {
        let (death_tx, death_rx) = channel::<()>();
        let worker = {
            let (cfg, fleet, registry, metrics) =
                (cfg.clone(), fleet.clone(), registry.clone(), metrics.clone());
            let ready = ready.take();
            // fleet-wide spawn index: engine rng stream blocks
            // (`idx << 32`) stay disjoint across slots and respawns
            let idx = fleet.alloc_worker_idx();
            std::thread::Builder::new().name(format!("diffaxe-engine-{idx}")).spawn(move || {
                // dropped on any exit — including a panic — so the
                // supervisor observes worker death as a disconnect
                let _death = death_tx;
                worker_main(idx, cfg, fleet, slot, registry, metrics, ready);
            })
        };
        let worker = match worker {
            Ok(w) => w,
            Err(e) => {
                give_up(&shared, &registry, &format!("worker thread spawn failed: {e}"));
                return;
            }
        };

        // park until the worker dies or a drain begins
        let stopping = loop {
            match death_rx.recv_timeout(Duration::from_millis(25)) {
                Ok(()) => {}
                Err(RecvTimeoutError::Timeout) => {
                    if shared.stopping() {
                        break true;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => break false,
            }
        };
        if stopping || shared.stopping() {
            drain(&shared, &registry, Some((worker, death_rx)));
            return;
        }
        if shared.is_dead() {
            // startup validation failed; the worker already reported the
            // typed error through `ready` — nothing to restart
            let _ = worker.join();
            return;
        }

        // reap the corpse for its panic message
        let crash_msg = match worker.join() {
            Ok(()) => "engine worker exited unexpectedly".to_string(),
            Err(payload) => fault::panic_message(payload.as_ref()),
        };

        // recover in-flight jobs: retry when the attempt budget allows,
        // fail terminally otherwise — never leave one `running`
        for inf in shared.take_inflight() {
            if inf.entry.state().terminal() {
                // crashed between finalize and reply: the clone delivers
                if let Some(r) = inf.reply {
                    let _ = r.send(inf.entry.result_now());
                }
                continue;
            }
            if inf.entry.attempts() < cfg.max_attempts && registry.requeue(&inf.entry) {
                shared.requeue_front(Msg::Run { entry: inf.entry, reply: inf.reply });
            } else {
                let resp = Response::error(
                    ErrorCode::Internal,
                    format!("engine worker crashed: {crash_msg}"),
                );
                registry.finalize(&inf.entry, JobState::Failed, resp.clone());
                if let Some(r) = inf.reply {
                    let _ = r.send(resp);
                }
            }
        }

        restarts += 1;
        if restarts > cfg.max_worker_restarts {
            give_up(
                &shared,
                &registry,
                &format!(
                    "engine worker unavailable: {} restarts exhausted (last crash: {crash_msg})",
                    cfg.max_worker_restarts
                ),
            );
            return;
        }
        metrics.worker_restart();

        // bounded exponential backoff, interruptible by a drain
        let backoff =
            (cfg.restart_backoff * (1u32 << (restarts - 1).min(6))).min(Duration::from_secs(5));
        let until = Instant::now() + backoff;
        loop {
            if shared.stopping() {
                drain(&shared, &registry, None);
                return;
            }
            let remaining = until.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break;
            }
            std::thread::sleep(remaining.min(Duration::from_millis(10)));
        }
    }
}

/// Restart budget exhausted (or the worker thread cannot even spawn):
/// mark the service dead and fail everything still pending so no waiter
/// blocks forever. Admission rejects from here on.
fn give_up(shared: &Shared, registry: &JobRegistry, reason: &str) {
    shared.mark_dead();
    for Msg::Run { entry, reply } in shared.drain_queue() {
        let resp = Response::error(ErrorCode::Internal, reason.to_string());
        registry.finalize(&entry, JobState::Failed, resp.clone());
        if let Some(r) = reply {
            let _ = r.send(resp);
        }
    }
    for inf in shared.take_inflight() {
        if !inf.entry.state().terminal() {
            let resp = Response::error(ErrorCode::Internal, reason.to_string());
            registry.finalize(&inf.entry, JobState::Failed, resp);
        }
        if let Some(r) = inf.reply {
            let _ = r.send(inf.entry.result_now());
        }
    }
}

/// Graceful drain (see the module docs for the ordering contract):
/// cancel queued work, flag in-flight work, give the worker until the
/// deadline, then force-cancel the rest so every watcher wakes.
fn drain(
    shared: &Shared,
    registry: &JobRegistry,
    worker: Option<(JoinHandle<()>, Receiver<()>)>,
) {
    let deadline = shared.drain_deadline();
    let start = Instant::now();
    // (1) queued jobs never ran: terminally cancel them now
    for Msg::Run { entry, reply } in shared.drain_queue() {
        entry.cancel_flag().store(true, Ordering::SeqCst);
        registry.force_cancel(&entry);
        if let Some(r) = reply {
            let _ = r.send(entry.result_now());
        }
    }
    // (2) in-flight work stops at its next batch boundary
    shared.cancel_inflight();
    // (3) the worker gets the remainder of the deadline to finish
    if let Some((handle, death_rx)) = worker {
        let exited = loop {
            let elapsed = start.elapsed();
            if elapsed >= deadline {
                break false;
            }
            match death_rx.recv_timeout(deadline - elapsed) {
                Ok(()) => {}
                Err(RecvTimeoutError::Disconnected) => break true,
                Err(RecvTimeoutError::Timeout) => break false,
            }
        };
        if exited {
            let _ = handle.join();
        } else {
            // deadline expired mid-search: detach the worker. Idempotent
            // first-wins finalization means a late completion cannot
            // regress the terminal states written below.
            drop(handle);
        }
    }
    // (4) force-cancel whatever is left so no watcher or waiter blocks
    for inf in shared.take_inflight() {
        if !inf.entry.state().terminal() {
            registry.force_cancel(&inf.entry);
        }
        if let Some(r) = inf.reply {
            let _ = r.send(inf.entry.result_now());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::SearchRequest;
    use crate::dse::api::{Budget, Objective, OptimizerKind, SearchOutcome, StopReason};
    use crate::workload::Gemm;

    fn request() -> SearchRequest {
        SearchRequest::new(
            Objective::MinEdp { g: Gemm::new(8, 8, 8) },
            Budget::evals(2),
            OptimizerKind::RandomSearch,
        )
    }

    #[test]
    fn admission_bounds_queue_depth() {
        let metrics = Arc::new(Metrics::new());
        let reg = JobRegistry::new(metrics.clone());
        let shared = Shared::new(2, Duration::from_secs(1));
        for _ in 0..2 {
            assert!(shared.admit(&metrics, || reg.submit(request()), None).is_ok());
        }
        match shared.admit(&metrics, || reg.submit(request()), None) {
            Err(Response::Error { code, retry_after_ms, .. }) => {
                assert_eq!(code, ErrorCode::Overloaded);
                assert!(retry_after_ms.is_some());
            }
            other => panic!("expected overloaded rejection, got {other:?}"),
        }
        assert_eq!(metrics.snapshot().jobs_shed, 1);
        // only the two admitted jobs are queued, FIFO
        assert!(shared.pop(Duration::from_millis(1)).is_some());
        assert!(shared.pop(Duration::from_millis(1)).is_some());
        assert!(shared.pop(Duration::from_millis(1)).is_none());
    }

    #[test]
    fn stop_closes_admissions_and_dispatch() {
        let metrics = Arc::new(Metrics::new());
        let reg = JobRegistry::new(metrics.clone());
        let shared = Shared::new(8, Duration::from_secs(1));
        shared.admit(&metrics, || reg.submit(request()), None).unwrap();
        shared.begin_stop();
        assert!(shared.pop(Duration::from_millis(1)).is_none(), "stop gates dispatch");
        match shared.admit(&metrics, || reg.submit(request()), None) {
            Err(Response::Error { code, retry_after_ms, .. }) => {
                assert_eq!(code, ErrorCode::Overloaded);
                assert!(retry_after_ms.is_none(), "drain rejection carries no retry hint");
            }
            other => panic!("expected drain rejection, got {other:?}"),
        }
        // the queued message is still there for the drain to finalize
        assert_eq!(shared.drain_queue().len(), 1);
    }

    #[test]
    fn inflight_roster_prunes_terminal_entries() {
        let metrics = Arc::new(Metrics::new());
        let reg = JobRegistry::new(metrics.clone());
        let shared = Shared::new(8, Duration::from_secs(1));
        let entry = reg.submit(request());
        shared.track(&entry, &None);
        shared.prune_terminal();
        assert_eq!(shared.take_inflight().len(), 1, "live jobs stay on the roster");
        shared.track(&entry, &None);
        reg.start(&entry);
        reg.finalize(
            &entry,
            JobState::Done,
            Response::Outcome(SearchOutcome::empty("random", StopReason::Completed)),
        );
        shared.prune_terminal();
        assert!(shared.take_inflight().is_empty(), "terminal jobs are pruned");
    }
}
