//! L3 coordinator — the DiffAxE DSE *service*: a fleet of supervised
//! engine workers ([`fleet`]) each owning a [`crate::dse::Session`],
//! least-loaded / work-stealing dispatch, continuous batching of
//! generation searches into the fixed-batch diffusion sampler, a
//! job-oriented search lifecycle, a versioned newline-JSON TCP front end
//! (see [`protocol`]), and service metrics.
//!
//! # Job lifecycle
//!
//! Every search the service accepts becomes a job in the
//! [`service::JobRegistry`]:
//!
//! ```text
//!              submit                    engine picks up
//!   client ───────────────▶ queued ─────────────────────▶ running
//!                             │                             │
//!                             │ cancel                      ├─ completes / deadline /
//!                             ▼                             │  budget ──▶ done
//!                          cancelled ◀── cancel (partial ───┤
//!                          (empty)        outcome kept)     ├─ error / panic ──▶ failed
//!                                                           └─ worker crash ──▶ requeued
//!                                                              (≤ max_attempts) or failed
//! ```
//!
//! * `submit` answers a `job_id` immediately; `status` / `jobs` / `cancel`
//!   are registry queries that never wait behind a running search.
//! * Admission is bounded ([`service::ServiceConfig::max_queued`]): an
//!   over-capacity submit is shed with a structured `overloaded` error
//!   carrying a `retry_after_ms` hint, never silently queued.
//! * A running search polls its cancellation flag and deadline **between
//!   evaluation batches** (see [`crate::dse::SearchCtx`]), so `cancel`
//!   and `Budget::wall_clock_s` stop it promptly while keeping every
//!   design evaluated so far (`SearchOutcome::stopped` records why).
//! * `watch` streams coalesced progress heartbeats (drop-to-latest — a
//!   slow reader skips intermediate events, never queues them) followed
//!   by the terminal outcome line.
//! * Synchronous v1/v2 `search` / `batch` requests still work
//!   byte-compatibly: they are submit-plus-wait over the same registry.
//! * Terminal jobs are retained for late `status` queries up to
//!   [`service::MAX_RETAINED_JOBS`], then GC'd oldest-first.
//!
//! # Supervision
//!
//! Each of the fleet's workers runs under its own supervisor
//! ([`supervisor`]): panics inside a search are isolated to that job; a
//! dead worker is respawned with bounded exponential backoff and its
//! in-flight job retried or terminally failed; a slot that exhausts its
//! restart budget is skipped by dispatch while its siblings keep serving;
//! dropping the service drains gracefully (admissions close, queued jobs
//! cancel, every watcher wakes). The supervision tree, restart policy,
//! drain ordering, and the deterministic fault-injection sites that test
//! them are documented in `docs/INVARIANTS.md`.
//!
//! # Locking
//!
//! Every lock in this module is a [`crate::util::sync::TrackedMutex`]
//! with a static rank (supervisor queue → supervisor inflight → registry
//! → job core → connection semaphore → metrics); debug builds assert the
//! acquisition order, and `diffaxe lint` forbids raw `std::sync` locks
//! outside the facade. The lock-rank table and the rules live in
//! `docs/INVARIANTS.md`.

pub mod fleet;
pub mod metrics;
pub mod protocol;
pub mod server;
pub mod service;
pub mod supervisor;

pub use metrics::Metrics;
pub use protocol::{
    ErrorCode, JobInfo, JobState, Request, Response, SearchRequest, WireError, PROTOCOL_VERSION,
};
pub use service::{
    Handle, JobEntry, JobRegistry, Service, ServiceConfig, DEFAULT_TOP_K, MAX_RETAINED_JOBS,
};
pub use supervisor::NoEngineError;

// the wire's design unit is the DSE layer's report type
pub use crate::dse::api::DesignReport;
