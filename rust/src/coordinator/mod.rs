//! L3 coordinator — the DiffAxE generation *service*: a dedicated engine
//! thread owning the compiled PJRT executables, continuous batching of
//! generation requests into the fixed-batch diffusion sampler, a
//! newline-JSON TCP front end, and service metrics.

pub mod metrics;
pub mod protocol;
pub mod server;
pub mod service;

pub use metrics::Metrics;
pub use protocol::{DesignReport, Request, Response};
pub use service::{Handle, Service, ServiceConfig};
