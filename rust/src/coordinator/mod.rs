//! L3 coordinator — the DiffAxE DSE *service*: a dedicated engine thread
//! owning a [`crate::dse::Session`], continuous batching of
//! runtime-generation searches into the fixed-batch diffusion sampler, a
//! versioned newline-JSON TCP front end speaking generic
//! objective/budget/optimizer requests (see [`protocol`]), and service
//! metrics.

pub mod metrics;
pub mod protocol;
pub mod server;
pub mod service;

pub use metrics::Metrics;
pub use protocol::{
    ErrorCode, Request, Response, SearchRequest, WireError, PROTOCOL_VERSION,
};
pub use service::{Handle, Service, ServiceConfig, DEFAULT_TOP_K};

// the wire's design unit is the DSE layer's report type
pub use crate::dse::api::DesignReport;
