//! Service metrics: request counters, batch-occupancy and latency
//! histograms, plus job-lifecycle gauges fed by the
//! [`crate::coordinator::service::JobRegistry`]. Shared across threads
//! behind a mutex (contention is negligible at DSE request rates).

use super::protocol::JobState;
use crate::util::stats::LatencyHist;
use crate::util::sync::{rank, TrackedMutex};

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    designs_generated: u64,
    designs_evaluated: u64,
    sampler_calls: u64,
    batch_slots_used: u64,
    batch_slots_total: u64,
    errors: u64,
    /// cumulative eval-cache counters (absolute values mirrored from
    /// [`crate::dse::eval::EvalCache`] after each evaluation burst)
    cache_hits: u64,
    cache_misses: u64,
    // ---- job lifecycle (registry transitions) ---------------------------
    jobs_submitted: u64,
    jobs_completed: u64,
    jobs_cancelled: u64,
    jobs_failed: u64,
    /// submits rejected by admission control (never entered the registry)
    jobs_shed: u64,
    /// engine workers restarted by the supervisor after a crash
    worker_restarts: u64,
    /// gauge: configured fleet size (worker slots)
    workers: u64,
    /// gauge: workers currently executing or flushing work (RAII-tracked
    /// via [`Metrics::busy`], so a panicking worker still decrements)
    worker_busy: u64,
    /// messages taken from a sibling slot's deque by an idle worker
    steals: u64,
    /// gauge: jobs accepted but not yet started
    jobs_queued: u64,
    /// gauge: jobs currently executing on the engine thread
    jobs_active: u64,
    /// gauge: occupied coalesced progress-event slots (≤ 1 per live job —
    /// the watch stream is drop-to-latest, so this is the whole queue)
    event_queue_depth: u64,
    request_latency: LatencyHist,
    sampler_latency: LatencyHist,
}

/// Thread-safe metrics sink. Its lock is the highest-ranked in the
/// registry/service cluster ([`rank::METRICS`]) — but by convention every
/// caller records *after* releasing registry/job locks, so it behaves as
/// a leaf (see the lock-rank table in `docs/INVARIANTS.md`).
#[derive(Debug)]
pub struct Metrics {
    inner: TrackedMutex<Inner>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics { inner: TrackedMutex::new("metrics.inner", rank::METRICS, Inner::default()) }
    }
}

/// Point-in-time snapshot for reporting.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub designs_generated: u64,
    pub designs_evaluated: u64,
    pub sampler_calls: u64,
    pub errors: u64,
    /// mean fraction of sampler batch slots carrying real requests
    pub batch_occupancy: f64,
    /// cumulative evaluation-cache hits/misses (see
    /// [`crate::dse::eval::EvalCache`])
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// job lifecycle: cumulative counters…
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_cancelled: u64,
    pub jobs_failed: u64,
    /// submits rejected by admission control (not counted in `jobs_submitted`)
    pub jobs_shed: u64,
    /// engine workers restarted by the supervisor after a crash
    pub worker_restarts: u64,
    /// messages stolen from sibling deques by idle workers
    pub steals: u64,
    /// …and point-in-time gauges
    pub workers: u64,
    /// workers currently executing or flushing work
    pub worker_busy: u64,
    pub jobs_queued: u64,
    pub jobs_active: u64,
    /// occupied coalesced progress-event slots (drop-to-latest queue depth)
    pub event_queue_depth: u64,
    pub request_p50_us: f64,
    pub request_p99_us: f64,
    pub sampler_mean_us: f64,
}

impl Snapshot {
    /// Fraction of evaluations served from the memo table.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self, latency_us: f64, designs: usize) {
        let mut m = self.inner.lock();
        m.requests += 1;
        m.designs_generated += designs as u64;
        m.request_latency.record_us(latency_us);
    }

    pub fn record_sampler_call(&self, latency_us: f64, slots_used: usize, slots_total: usize) {
        let mut m = self.inner.lock();
        m.sampler_calls += 1;
        m.batch_slots_used += slots_used as u64;
        m.batch_slots_total += slots_total as u64;
        m.sampler_latency.record_us(latency_us);
    }

    pub fn record_evaluations(&self, n: usize) {
        self.inner.lock().designs_evaluated += n as u64;
    }

    /// Mirror the eval-cache counters (absolute cumulative values; the
    /// cache is the source of truth, this just makes them scrapeable).
    pub fn record_cache(&self, hits: u64, misses: u64) {
        let mut m = self.inner.lock();
        m.cache_hits = hits;
        m.cache_misses = misses;
    }

    pub fn record_error(&self) {
        self.inner.lock().errors += 1;
    }

    /// A job entered the registry (state `queued`).
    pub fn job_submitted(&self) {
        let mut m = self.inner.lock();
        m.jobs_submitted += 1;
        m.jobs_queued += 1;
    }

    /// A job left the queue and started executing.
    pub fn job_started(&self) {
        let mut m = self.inner.lock();
        m.jobs_queued = m.jobs_queued.saturating_sub(1);
        m.jobs_active += 1;
    }

    /// A submit was rejected by admission control before reaching the
    /// registry.
    pub fn job_shed(&self) {
        self.inner.lock().jobs_shed += 1;
    }

    /// A running job went back to `queued` for a retry after its worker
    /// crashed (inverse of [`Metrics::job_started`]).
    pub fn job_requeued(&self) {
        let mut m = self.inner.lock();
        m.jobs_active = m.jobs_active.saturating_sub(1);
        m.jobs_queued += 1;
    }

    /// The supervisor restarted a crashed engine worker.
    pub fn worker_restart(&self) {
        self.inner.lock().worker_restarts += 1;
    }

    /// Record the configured fleet size (a gauge, set once at startup).
    pub fn set_workers(&self, n: usize) {
        self.inner.lock().workers = n as u64;
    }

    /// Mark this worker busy for the guard's lifetime. The decrement
    /// lives in `Drop`, so it runs even if the guarded work panics —
    /// the `worker_busy` gauge cannot leak upward across crashes.
    pub fn busy(&self) -> BusyGuard<'_> {
        self.inner.lock().worker_busy += 1;
        BusyGuard { metrics: self }
    }

    /// An idle worker stole a queued message from a sibling's deque.
    pub fn steal(&self) {
        self.inner.lock().steals += 1;
    }

    /// A job reached a terminal state. `was_running` distinguishes which
    /// gauge to decrement; `had_buffered_event` frees its coalesced
    /// progress-event slot.
    pub fn job_finished(&self, state: JobState, was_running: bool, had_buffered_event: bool) {
        let mut m = self.inner.lock();
        if was_running {
            m.jobs_active = m.jobs_active.saturating_sub(1);
        } else {
            m.jobs_queued = m.jobs_queued.saturating_sub(1);
        }
        if had_buffered_event {
            m.event_queue_depth = m.event_queue_depth.saturating_sub(1);
        }
        match state {
            JobState::Cancelled => m.jobs_cancelled += 1,
            JobState::Failed => m.jobs_failed += 1,
            _ => m.jobs_completed += 1,
        }
    }

    /// A progress event landed in a previously-empty coalescing slot
    /// (replacing a buffered event keeps the depth unchanged).
    pub fn event_buffered(&self) {
        self.inner.lock().event_queue_depth += 1;
    }

    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock();
        Snapshot {
            requests: m.requests,
            designs_generated: m.designs_generated,
            designs_evaluated: m.designs_evaluated,
            sampler_calls: m.sampler_calls,
            errors: m.errors,
            batch_occupancy: if m.batch_slots_total == 0 {
                0.0
            } else {
                m.batch_slots_used as f64 / m.batch_slots_total as f64
            },
            cache_hits: m.cache_hits,
            cache_misses: m.cache_misses,
            jobs_submitted: m.jobs_submitted,
            jobs_completed: m.jobs_completed,
            jobs_cancelled: m.jobs_cancelled,
            jobs_failed: m.jobs_failed,
            jobs_shed: m.jobs_shed,
            worker_restarts: m.worker_restarts,
            steals: m.steals,
            workers: m.workers,
            worker_busy: m.worker_busy,
            jobs_queued: m.jobs_queued,
            jobs_active: m.jobs_active,
            event_queue_depth: m.event_queue_depth,
            request_p50_us: m.request_latency.percentile_us(50.0),
            request_p99_us: m.request_latency.percentile_us(99.0),
            sampler_mean_us: m.sampler_latency.mean_us(),
        }
    }
}

/// RAII token from [`Metrics::busy`]; holds the `worker_busy` increment
/// until dropped (including during a panic unwind).
#[derive(Debug)]
pub struct BusyGuard<'a> {
    metrics: &'a Metrics,
}

impl Drop for BusyGuard<'_> {
    fn drop(&mut self) {
        let mut m = self.metrics.inner.lock();
        m.worker_busy = m.worker_busy.saturating_sub(1);
    }
}

impl std::fmt::Display for Snapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "requests={} designs={} evals={} sampler_calls={} occupancy={:.2} \
             cache_hits={} cache_misses={} cache_hit_rate={:.3} \
             jobs_submitted={} jobs_queued={} jobs_active={} jobs_completed={} \
             jobs_cancelled={} jobs_failed={} jobs_shed={} worker_restarts={} \
             workers={} worker_busy={} steals={} event_queue_depth={} \
             p50={:.0}us p99={:.0}us sampler_mean={:.0}us errors={}",
            self.requests,
            self.designs_generated,
            self.designs_evaluated,
            self.sampler_calls,
            self.batch_occupancy,
            self.cache_hits,
            self.cache_misses,
            self.cache_hit_rate(),
            self.jobs_submitted,
            self.jobs_queued,
            self.jobs_active,
            self.jobs_completed,
            self.jobs_cancelled,
            self.jobs_failed,
            self.jobs_shed,
            self.worker_restarts,
            self.workers,
            self.worker_busy,
            self.steals,
            self.event_queue_depth,
            self.request_p50_us,
            self.request_p99_us,
            self.sampler_mean_us,
            self.errors
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_records() {
        let m = Metrics::new();
        m.record_request(1000.0, 10);
        m.record_request(2000.0, 20);
        m.record_sampler_call(5000.0, 30, 128);
        m.record_evaluations(30);
        m.record_cache(75, 25);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.designs_generated, 30);
        assert_eq!(s.designs_evaluated, 30);
        assert_eq!(s.sampler_calls, 1);
        assert_eq!(s.errors, 1);
        assert!((s.batch_occupancy - 30.0 / 128.0).abs() < 1e-9);
        assert_eq!((s.cache_hits, s.cache_misses), (75, 25));
        assert!((s.cache_hit_rate() - 0.75).abs() < 1e-12);
        assert!(s.request_p50_us > 0.0);
        // record_cache mirrors absolutes, it does not accumulate
        m.record_cache(80, 40);
        assert_eq!(m.snapshot().cache_hits, 80);
    }

    #[test]
    fn empty_metrics_safe() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.batch_occupancy, 0.0);
        assert_eq!(s.cache_hit_rate(), 0.0);
        assert_eq!((s.jobs_queued, s.jobs_active, s.event_queue_depth), (0, 0, 0));
    }

    #[test]
    fn job_lifecycle_gauges_balance() {
        let m = Metrics::new();
        // three jobs: one completes, one cancels mid-run, one cancels queued
        for _ in 0..3 {
            m.job_submitted();
        }
        m.job_started();
        m.event_buffered();
        m.job_started();
        let s = m.snapshot();
        assert_eq!((s.jobs_submitted, s.jobs_queued, s.jobs_active), (3, 1, 2));
        assert_eq!(s.event_queue_depth, 1);
        m.job_finished(JobState::Done, true, true);
        m.job_finished(JobState::Cancelled, true, false);
        m.job_finished(JobState::Cancelled, false, false);
        let s = m.snapshot();
        assert_eq!((s.jobs_queued, s.jobs_active, s.event_queue_depth), (0, 0, 0));
        assert_eq!((s.jobs_completed, s.jobs_cancelled, s.jobs_failed), (1, 2, 0));
        // gauges appear in the scrape line
        let line = s.to_string();
        assert!(line.contains("jobs_active=0"), "{line}");
        assert!(line.contains("event_queue_depth=0"), "{line}");
    }

    #[test]
    fn fleet_gauges_and_busy_guard() {
        let m = Metrics::new();
        m.set_workers(4);
        m.steal();
        m.steal();
        {
            let _a = m.busy();
            let _b = m.busy();
            assert_eq!(m.snapshot().worker_busy, 2);
        }
        let s = m.snapshot();
        assert_eq!((s.workers, s.worker_busy, s.steals), (4, 0, 2));
        // the guard decrements even when the guarded work panics
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.busy();
            panic!("boom");
        }));
        assert!(caught.is_err());
        assert_eq!(m.snapshot().worker_busy, 0);
        let line = m.snapshot().to_string();
        assert!(line.contains("workers=4"), "{line}");
        assert!(line.contains("steals=2"), "{line}");
    }

    #[test]
    fn shed_retry_and_restart_counters() {
        let m = Metrics::new();
        m.job_shed();
        m.job_shed();
        // one job retried once: started, requeued, started again, done
        m.job_submitted();
        m.job_started();
        m.job_requeued();
        m.job_started();
        m.job_finished(JobState::Done, true, false);
        m.worker_restart();
        let s = m.snapshot();
        assert_eq!((s.jobs_shed, s.worker_restarts), (2, 1));
        // shed jobs never enter the registry counters
        assert_eq!(s.jobs_submitted, 1);
        // the requeue round-trip leaves the gauges balanced
        assert_eq!((s.jobs_queued, s.jobs_active), (0, 0));
        let line = s.to_string();
        assert!(line.contains("jobs_shed=2"), "{line}");
        assert!(line.contains("worker_restarts=1"), "{line}");
    }
}
