//! Service metrics: request counters, batch-occupancy and latency
//! histograms. Shared across threads behind a mutex (contention is
//! negligible at DSE request rates).

use crate::util::stats::LatencyHist;
use std::sync::Mutex;

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    designs_generated: u64,
    designs_evaluated: u64,
    sampler_calls: u64,
    batch_slots_used: u64,
    batch_slots_total: u64,
    errors: u64,
    /// cumulative eval-cache counters (absolute values mirrored from
    /// [`crate::dse::eval::EvalCache`] after each evaluation burst)
    cache_hits: u64,
    cache_misses: u64,
    request_latency: LatencyHist,
    sampler_latency: LatencyHist,
}

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Point-in-time snapshot for reporting.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub designs_generated: u64,
    pub designs_evaluated: u64,
    pub sampler_calls: u64,
    pub errors: u64,
    /// mean fraction of sampler batch slots carrying real requests
    pub batch_occupancy: f64,
    /// cumulative evaluation-cache hits/misses (see
    /// [`crate::dse::eval::EvalCache`])
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub request_p50_us: f64,
    pub request_p99_us: f64,
    pub sampler_mean_us: f64,
}

impl Snapshot {
    /// Fraction of evaluations served from the memo table.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self, latency_us: f64, designs: usize) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        m.designs_generated += designs as u64;
        m.request_latency.record_us(latency_us);
    }

    pub fn record_sampler_call(&self, latency_us: f64, slots_used: usize, slots_total: usize) {
        let mut m = self.inner.lock().unwrap();
        m.sampler_calls += 1;
        m.batch_slots_used += slots_used as u64;
        m.batch_slots_total += slots_total as u64;
        m.sampler_latency.record_us(latency_us);
    }

    pub fn record_evaluations(&self, n: usize) {
        self.inner.lock().unwrap().designs_evaluated += n as u64;
    }

    /// Mirror the eval-cache counters (absolute cumulative values; the
    /// cache is the source of truth, this just makes them scrapeable).
    pub fn record_cache(&self, hits: u64, misses: u64) {
        let mut m = self.inner.lock().unwrap();
        m.cache_hits = hits;
        m.cache_misses = misses;
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap();
        Snapshot {
            requests: m.requests,
            designs_generated: m.designs_generated,
            designs_evaluated: m.designs_evaluated,
            sampler_calls: m.sampler_calls,
            errors: m.errors,
            batch_occupancy: if m.batch_slots_total == 0 {
                0.0
            } else {
                m.batch_slots_used as f64 / m.batch_slots_total as f64
            },
            cache_hits: m.cache_hits,
            cache_misses: m.cache_misses,
            request_p50_us: m.request_latency.percentile_us(50.0),
            request_p99_us: m.request_latency.percentile_us(99.0),
            sampler_mean_us: m.sampler_latency.mean_us(),
        }
    }
}

impl std::fmt::Display for Snapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "requests={} designs={} evals={} sampler_calls={} occupancy={:.2} \
             cache_hits={} cache_misses={} cache_hit_rate={:.3} \
             p50={:.0}us p99={:.0}us sampler_mean={:.0}us errors={}",
            self.requests,
            self.designs_generated,
            self.designs_evaluated,
            self.sampler_calls,
            self.batch_occupancy,
            self.cache_hits,
            self.cache_misses,
            self.cache_hit_rate(),
            self.request_p50_us,
            self.request_p99_us,
            self.sampler_mean_us,
            self.errors
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_records() {
        let m = Metrics::new();
        m.record_request(1000.0, 10);
        m.record_request(2000.0, 20);
        m.record_sampler_call(5000.0, 30, 128);
        m.record_evaluations(30);
        m.record_cache(75, 25);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.designs_generated, 30);
        assert_eq!(s.designs_evaluated, 30);
        assert_eq!(s.sampler_calls, 1);
        assert_eq!(s.errors, 1);
        assert!((s.batch_occupancy - 30.0 / 128.0).abs() < 1e-9);
        assert_eq!((s.cache_hits, s.cache_misses), (75, 25));
        assert!((s.cache_hit_rate() - 0.75).abs() < 1e-12);
        assert!(s.request_p50_us > 0.0);
        // record_cache mirrors absolutes, it does not accumulate
        m.record_cache(80, 40);
        assert_eq!(m.snapshot().cache_hits, 80);
    }

    #[test]
    fn empty_metrics_safe() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.batch_occupancy, 0.0);
        assert_eq!(s.cache_hit_rate(), 0.0);
    }
}
