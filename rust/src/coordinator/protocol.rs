//! Request/response types and their JSON wire encoding (newline-delimited
//! JSON over TCP — see [`super::server`]).
//!
//! # Versioning
//!
//! Every message may carry a `"v"` field. Requests without one are treated
//! as protocol v1 (the original four hardcoded request forms, kept as
//! deprecated parse-only aliases); `"v"` above [`PROTOCOL_VERSION`] yields
//! a structured [`Response::Error`] with code `unsupported_version` rather
//! than a dropped connection. Unknown JSON fields are ignored everywhere,
//! so additive evolution never breaks old peers.
//!
//! # v2 request forms
//!
//! One generic search request replaces the per-task variants — any
//! [`Objective`] × [`Budget`] × [`OptimizerKind`]:
//!
//! ```json
//! {"v":2,"type":"search",
//!  "objective":{"kind":"runtime","m":128,"k":768,"n":2304,"target_cycles":1e6},
//!  "budget":{"evals":16},
//!  "optimizer":"diffaxe"}
//! ```
//!
//! and a `batch` request carries several searches in one round-trip:
//!
//! ```json
//! {"v":2,"type":"batch","requests":[{"objective":…,"budget":…,"optimizer":…},…]}
//! ```
//!
//! Batch semantics: every item is validated before any runs (a detectably
//! bad pairing answers `bad_request` up front); execution is then
//! all-or-nothing — a mid-batch internal failure answers a single
//! `internal` error rather than a partial outcome list.
//!
//! # v3 request forms — jobs
//!
//! v3 extends the envelope *additively*: every v1/v2 line keeps parsing
//! and synchronous `search`/`batch` responses stay readable by v2 peers
//! (the new `stopped` outcome field rides on the existing unknown-field
//! tolerance). Long-running searches become first-class jobs:
//!
//! ```json
//! {"v":3,"type":"submit","objective":…,"budget":…,"optimizer":"dosa-gd"}
//! ```
//!
//! answers `{"status":"ok","job_id":"job-7","job_state":"queued"}`
//! immediately. The job is then driven with:
//!
//! * `{"v":3,"type":"status","job_id":"job-7"}` → one [`JobInfo`] line;
//! * `{"v":3,"type":"jobs"}` → every retained job;
//! * `{"v":3,"type":"cancel","job_id":"job-7"}` → raises the job's
//!   cancellation flag; the search stops at its next batch boundary and
//!   its *partial* outcome (`"stopped":"cancelled"`) is retained;
//! * `{"v":3,"type":"watch","job_id":"job-7"}` → **streams** NDJSON on the
//!   same connection: `{"type":"event",…}` progress heartbeats (evals
//!   done, current best, elapsed — coalesced drop-to-latest under
//!   backpressure), then one terminal `{"type":"outcome","job_id":…,…}`
//!   line, after which the connection accepts further requests.
//!
//! A search's `stopped` field is one of `completed | cancelled |
//! deadline_exceeded | budget_exhausted` ([`StopReason`]); budgets may
//! carry `wall_clock_s`, enforced server-side as a hard deadline.
//!
//! # Structured DSE (additive, v3 stays byte-compatible)
//!
//! Two objective kinds expose the §V structured search:
//!
//! ```json
//! {"kind":"structured_edp","model":"bert-base","stage":"prefill","seq":128,
//!  "platform":"asic-32nm","segments":3,
//!  "budget":{"pe":4096,"buf_kb":768,"bw":16}}
//! ```
//!
//! (`structured_perf` minimizes cycles instead of EDP; `budget` fields
//! default to the unconstrained envelope when absent.) Structured
//! outcomes carry an additive per-design `"segments"` array — the
//! per-segment sub-configurations next to the provisioned-envelope design
//! — which non-structured responses omit entirely, so every pre-existing
//! v1/v2/v3 line serializes byte-identically (guarded by the golden
//! fixture corpus in `tests/wire_fixtures.rs`).

use crate::design_space::structured::SharedBudget;
use crate::design_space::{HwConfig, LoopOrder};
use crate::dse::api::{
    Budget, DesignReport, Objective, OptimizerKind, SearchEvent, SearchOutcome, StopReason,
};
use crate::dse::llm::Platform;
use crate::dse::structured::StructuredSpec;
use crate::util::json::Json;
use crate::workload::{llm::DEFAULT_SEQ, Gemm, LlmModel, Stage};
use anyhow::{bail, Context, Result};

/// Highest protocol version this build speaks.
pub const PROTOCOL_VERSION: u32 = 3;

/// Lifecycle of a submitted search job (see
/// [`crate::coordinator::service::JobRegistry`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Accepted, waiting for the engine thread.
    Queued,
    /// Executing on the engine thread.
    Running,
    /// Finished with an outcome (including deadline/budget-truncated ones).
    Done,
    /// Cancelled; a partial outcome is retained if the search had started.
    Cancelled,
    /// The search errored; the error response is retained.
    Failed,
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Cancelled => "cancelled",
            JobState::Failed => "failed",
        }
    }

    pub fn from_name(s: &str) -> Option<JobState> {
        [JobState::Queued, JobState::Running, JobState::Done, JobState::Cancelled, JobState::Failed]
            .into_iter()
            .find(|j| j.name() == s)
    }

    /// True once the job can no longer change state.
    pub fn terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Cancelled | JobState::Failed)
    }
}

/// Point-in-time description of a job (the `status`/`jobs` wire unit).
#[derive(Debug, Clone, PartialEq)]
pub struct JobInfo {
    pub id: String,
    pub state: JobState,
    /// Wire name of the optimizer ([`OptimizerKind::name`]).
    pub optimizer: String,
    /// Human-readable objective description.
    pub objective: String,
    /// Objective evaluations finished so far (final count once terminal).
    pub evals: usize,
    /// Best (lowest) score seen so far, if any evaluation completed.
    pub best_score: Option<f64>,
    /// Seconds since submission (frozen at the terminal transition).
    pub elapsed_s: f64,
    /// Execution attempts (1 on the first run; >1 after a worker-crash
    /// retry). Serialized additively: only on `failed` jobs or when a
    /// retry happened, so pre-existing wire lines are byte-identical.
    pub attempts: u32,
}

/// Structured wire-error categories.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// malformed or semantically invalid request
    BadRequest,
    /// request's `"v"` is newer than [`PROTOCOL_VERSION`]
    UnsupportedVersion,
    /// the request was valid but serving it failed
    Internal,
    /// v3: admission control shed the request (queue full or service
    /// draining); retry after `retry_after_ms` when present
    Overloaded,
}

impl ErrorCode {
    pub fn name(&self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnsupportedVersion => "unsupported_version",
            ErrorCode::Internal => "internal",
            ErrorCode::Overloaded => "overloaded",
        }
    }

    pub fn from_name(s: &str) -> Option<ErrorCode> {
        [
            ErrorCode::BadRequest,
            ErrorCode::UnsupportedVersion,
            ErrorCode::Internal,
            ErrorCode::Overloaded,
        ]
        .into_iter()
        .find(|c| c.name() == s)
    }
}

/// A request that could not be decoded, with its error category — the
/// server turns this into a [`Response::Error`] on the same connection.
#[derive(Debug, Clone, PartialEq)]
pub struct WireError {
    pub code: ErrorCode,
    pub message: String,
}

impl WireError {
    fn bad(message: impl Into<String>) -> WireError {
        WireError { code: ErrorCode::BadRequest, message: message.into() }
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code.name(), self.message)
    }
}

impl std::error::Error for WireError {}

/// One search: what to optimize, how much to spend, and with which
/// strategy.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchRequest {
    pub objective: Objective,
    pub budget: Budget,
    pub optimizer: OptimizerKind,
    /// cap on ranked designs in the response (`None` = server default)
    pub top_k: Option<usize>,
}

impl SearchRequest {
    pub fn new(objective: Objective, budget: Budget, optimizer: OptimizerKind) -> SearchRequest {
        SearchRequest { objective, budget, optimizer, top_k: None }
    }
}

/// A DSE request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// one generic search, answered synchronously (submit + wait)
    Search(SearchRequest),
    /// several searches served in one round-trip
    Batch(Vec<SearchRequest>),
    /// service introspection
    Metrics,
    /// v3: enqueue a search as a job, answer `job_id` immediately
    Submit(SearchRequest),
    /// v3: one job's current [`JobInfo`]
    Status { job_id: String },
    /// v3: raise a job's cancellation flag
    Cancel { job_id: String },
    /// v3: list every retained job
    Jobs,
    /// v3: stream `event` lines then the terminal `outcome` line
    Watch { job_id: String },
}

/// A DSE response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// protocol-v1 result shape (parse compatibility; v2 serves `Outcome`)
    Designs(Vec<DesignReport>),
    /// one search's full outcome (ranked designs + trace + accounting)
    Outcome(SearchOutcome),
    /// outcomes of a `Batch` request, in request order
    Batch(Vec<SearchOutcome>),
    MetricsText(String),
    /// v3: a job was accepted
    Submitted { job_id: String, state: JobState },
    /// v3: one job's status (`status` and `cancel` answer this)
    Job(JobInfo),
    /// v3: every retained job
    Jobs(Vec<JobInfo>),
    /// v3: one progress heartbeat on a `watch` stream
    Event { job_id: String, event: SearchEvent },
    /// v3: the terminal line of a `watch` stream
    JobOutcome { job_id: String, outcome: SearchOutcome },
    Error {
        code: ErrorCode,
        message: String,
        /// v3, additive: backoff hint on `overloaded` errors; omitted
        /// from the wire when `None`.
        retry_after_ms: Option<u64>,
    },
}

impl Response {
    pub fn error(code: ErrorCode, message: impl Into<String>) -> Response {
        Response::Error { code, message: message.into(), retry_after_ms: None }
    }

    /// An [`ErrorCode::Overloaded`] error carrying a retry hint.
    pub fn overloaded(message: impl Into<String>, retry_after_ms: u64) -> Response {
        Response::Error {
            code: ErrorCode::Overloaded,
            message: message.into(),
            retry_after_ms: Some(retry_after_ms),
        }
    }
}

// ---------------------------------------------------------------------------
// objective / budget encoding
// ---------------------------------------------------------------------------

fn gemm_from_json(j: &Json) -> Result<Gemm, WireError> {
    let dim = |k: &str| -> Result<u32, WireError> {
        let v = j.get(k).as_usize().ok_or_else(|| WireError::bad(format!("missing '{k}'")))?;
        if v < 1 || v > u32::MAX as usize {
            return Err(WireError::bad(format!("'{k}' out of range: {v}")));
        }
        Ok(v as u32)
    };
    Ok(Gemm::new(dim("m")?, dim("k")?, dim("n")?))
}

fn gemm_fields(g: &Gemm) -> Vec<(&'static str, Json)> {
    vec![
        ("m", Json::Num(g.m as f64)),
        ("k", Json::Num(g.k as f64)),
        ("n", Json::Num(g.n as f64)),
    ]
}

fn objective_to_json(o: &Objective) -> Json {
    match o {
        Objective::Runtime { g, target_cycles } => {
            let mut fields = vec![("kind", Json::Str("runtime".into()))];
            fields.extend(gemm_fields(g));
            fields.push(("target_cycles", Json::Num(*target_cycles)));
            Json::obj(fields)
        }
        Objective::MinEdp { g } => {
            let mut fields = vec![("kind", Json::Str("min_edp".into()))];
            fields.extend(gemm_fields(g));
            Json::obj(fields)
        }
        Objective::MaxPerf { g } => {
            let mut fields = vec![("kind", Json::Str("max_perf".into()))];
            fields.extend(gemm_fields(g));
            Json::obj(fields)
        }
        Objective::LlmEdp { model, stage, seq, platform } => Json::obj(vec![
            ("kind", Json::Str("llm_edp".into())),
            ("model", Json::Str(model.wire_name().into())),
            ("stage", Json::Str(stage.name().into())),
            ("seq", Json::Num(*seq as f64)),
            ("platform", Json::Str(platform.name().into())),
        ]),
        Objective::StructuredEdp { spec } => structured_to_json("structured_edp", spec),
        Objective::StructuredPerf { spec } => structured_to_json("structured_perf", spec),
    }
}

/// Additive v3 objective form for §V structured DSE. `budget` carries the
/// shared accelerator envelope; absent fields fall back to the
/// unconstrained default, so minimal requests stay short.
fn structured_to_json(kind: &'static str, spec: &StructuredSpec) -> Json {
    Json::obj(vec![
        ("kind", Json::Str(kind.into())),
        ("model", Json::Str(spec.model.wire_name().into())),
        ("stage", Json::Str(spec.stage.name().into())),
        ("seq", Json::Num(spec.seq as f64)),
        ("platform", Json::Str(spec.platform.name().into())),
        ("segments", Json::Num(spec.segments as f64)),
        (
            "budget",
            Json::obj(vec![
                ("pe", Json::Num(spec.budget.pe as f64)),
                ("buf_kb", Json::Num(spec.budget.buf_b as f64 / 1024.0)),
                ("bw", Json::Num(spec.budget.bw as f64)),
            ]),
        ),
    ])
}

/// Range-checked u32 wire field: a value that does not fit is a client
/// error, never a silent `as` wrap that would bypass spec validation.
fn wire_u32(j: &Json, key: &str, default: u32) -> Result<u32, WireError> {
    match j.get(key).as_usize() {
        None => Ok(default),
        Some(v) => u32::try_from(v)
            .map_err(|_| WireError::bad(format!("'{key}' out of range: {v}"))),
    }
}

fn structured_from_json(j: &Json, edp: bool) -> Result<Objective, WireError> {
    let model_name = j.get("model").as_str().unwrap_or("");
    let model = LlmModel::from_name(model_name)
        .ok_or_else(|| WireError::bad(format!("unknown model {model_name:?}")))?;
    let stage_name = j.get("stage").as_str().unwrap_or("prefill");
    let stage = Stage::from_name(stage_name)
        .ok_or_else(|| WireError::bad(format!("unknown stage {stage_name:?}")))?;
    let platform_name = j.get("platform").as_str().unwrap_or("asic-32nm");
    let platform = Platform::from_name(platform_name)
        .ok_or_else(|| WireError::bad(format!("unknown platform {platform_name:?}")))?;
    let seq = wire_u32(j, "seq", DEFAULT_SEQ)?;
    let segments = wire_u32(j, "segments", 3)?;
    let bj = j.get("budget");
    let defaults = SharedBudget::default();
    let budget = SharedBudget {
        pe: wire_u32(bj, "pe", defaults.pe)?,
        buf_b: bj
            .get("buf_kb")
            .as_f64()
            .map(|kb| (kb * 1024.0).round() as u64)
            .unwrap_or(defaults.buf_b),
        bw: wire_u32(bj, "bw", defaults.bw)?,
    };
    let spec = StructuredSpec { model, stage, seq, platform, segments, budget };
    spec.validate().map_err(WireError::bad)?;
    Ok(if edp {
        Objective::StructuredEdp { spec }
    } else {
        Objective::StructuredPerf { spec }
    })
}

fn objective_from_json(j: &Json) -> Result<Objective, WireError> {
    let kind = j
        .get("kind")
        .as_str()
        .ok_or_else(|| WireError::bad("objective missing 'kind'"))?;
    Ok(match kind {
        "runtime" => Objective::Runtime {
            g: gemm_from_json(j)?,
            target_cycles: j
                .get("target_cycles")
                .as_f64()
                .ok_or_else(|| WireError::bad("missing 'target_cycles'"))?,
        },
        "min_edp" => Objective::MinEdp { g: gemm_from_json(j)? },
        "max_perf" => Objective::MaxPerf { g: gemm_from_json(j)? },
        "llm_edp" => {
            let model_name = j.get("model").as_str().unwrap_or("");
            let model = LlmModel::from_name(model_name)
                .ok_or_else(|| WireError::bad(format!("unknown model {model_name:?}")))?;
            let stage_name = j.get("stage").as_str().unwrap_or("prefill");
            let stage = Stage::from_name(stage_name)
                .ok_or_else(|| WireError::bad(format!("unknown stage {stage_name:?}")))?;
            let platform_name = j.get("platform").as_str().unwrap_or("asic-32nm");
            let platform = Platform::from_name(platform_name)
                .ok_or_else(|| WireError::bad(format!("unknown platform {platform_name:?}")))?;
            let seq = j.get("seq").as_usize().unwrap_or(DEFAULT_SEQ as usize) as u32;
            Objective::LlmEdp { model, stage, seq, platform }
        }
        "structured_edp" => structured_from_json(j, true)?,
        "structured_perf" => structured_from_json(j, false)?,
        other => return Err(WireError::bad(format!("unknown objective kind {other:?}"))),
    })
}

fn budget_to_json(b: &Budget) -> Json {
    let mut fields = vec![("evals", Json::Num(b.evals as f64))];
    if let Some(pc) = b.per_class {
        fields.push(("per_class", Json::Num(pc as f64)));
    }
    if let Some(w) = b.wall_clock_s {
        fields.push(("wall_clock_s", Json::Num(w)));
    }
    Json::obj(fields)
}

fn budget_from_json(j: &Json) -> Result<Budget, WireError> {
    if matches!(j, Json::Null) {
        return Ok(Budget::default());
    }
    let mut b = Budget::default();
    if let Some(n) = j.get("evals").as_usize() {
        b.evals = n;
    }
    b.per_class = j.get("per_class").as_usize();
    b.wall_clock_s = j.get("wall_clock_s").as_f64();
    Ok(b)
}

fn search_from_json(j: &Json) -> Result<SearchRequest, WireError> {
    let objective = objective_from_json(j.get("objective"))?;
    let budget = budget_from_json(j.get("budget"))?;
    let opt_name = j.get("optimizer").as_str().unwrap_or("diffaxe");
    let optimizer = OptimizerKind::parse(opt_name)
        .ok_or_else(|| WireError::bad(format!("unknown optimizer {opt_name:?}")))?;
    Ok(SearchRequest { objective, budget, optimizer, top_k: j.get("top_k").as_usize() })
}

fn search_to_json(s: &SearchRequest) -> Json {
    let mut fields = vec![
        ("objective", objective_to_json(&s.objective)),
        ("budget", budget_to_json(&s.budget)),
        ("optimizer", Json::Str(s.optimizer.name().into())),
    ];
    if let Some(k) = s.top_k {
        fields.push(("top_k", Json::Num(k as f64)));
    }
    Json::obj(fields)
}

// ---------------------------------------------------------------------------
// requests
// ---------------------------------------------------------------------------

impl Request {
    /// Decode a request. Accepts the generic v2 forms, the v3 job forms
    /// (`submit`/`status`/`cancel`/`jobs`/`watch`), and the deprecated
    /// v1 aliases (`generate`, `edp_search`, `perf_search`, `llm_search`),
    /// which parse into the equivalent [`SearchRequest`] with the
    /// `diffaxe` optimizer.
    pub fn from_json(j: &Json) -> Result<Request, WireError> {
        if let Some(v) = j.get("v").as_f64() {
            if v > PROTOCOL_VERSION as f64 {
                return Err(WireError {
                    code: ErrorCode::UnsupportedVersion,
                    message: format!("request v{v} exceeds supported v{PROTOCOL_VERSION}"),
                });
            }
        }
        let ty = j
            .get("type")
            .as_str()
            .ok_or_else(|| WireError::bad("request missing 'type'"))?;
        let job_id = |j: &Json| -> Result<String, WireError> {
            Ok(j.get("job_id")
                .as_str()
                .ok_or_else(|| WireError::bad("missing 'job_id'"))?
                .to_string())
        };
        Ok(match ty {
            "search" => Request::Search(search_from_json(j)?),
            "submit" => Request::Submit(search_from_json(j)?),
            "status" => Request::Status { job_id: job_id(j)? },
            "cancel" => Request::Cancel { job_id: job_id(j)? },
            "jobs" => Request::Jobs,
            "watch" => Request::Watch { job_id: job_id(j)? },
            "batch" => {
                let items = j
                    .get("requests")
                    .as_arr()
                    .ok_or_else(|| WireError::bad("batch missing 'requests'"))?;
                if items.is_empty() {
                    return Err(WireError::bad("batch must carry at least one search"));
                }
                Request::Batch(items.iter().map(search_from_json).collect::<Result<_, _>>()?)
            }
            "metrics" => Request::Metrics,
            // ---- deprecated v1 aliases ------------------------------------
            // each alias pins `top_k` to its v1 response shape: `generate`
            // returned `count` designs, the three searches their single best
            "generate" => {
                let count = j.get("count").as_usize().unwrap_or(16);
                Request::Search(SearchRequest {
                    objective: Objective::Runtime {
                        g: gemm_from_json(j)?,
                        target_cycles: j
                            .get("target_cycles")
                            .as_f64()
                            .ok_or_else(|| WireError::bad("missing 'target_cycles'"))?,
                    },
                    budget: Budget::evals(count),
                    optimizer: OptimizerKind::DiffAxE,
                    top_k: Some(count),
                })
            }
            "edp_search" => Request::Search(SearchRequest {
                objective: Objective::MinEdp { g: gemm_from_json(j)? },
                budget: Budget::default()
                    .with_per_class(j.get("per_class").as_usize().unwrap_or(32)),
                optimizer: OptimizerKind::DiffAxE,
                top_k: Some(1),
            }),
            "perf_search" => Request::Search(SearchRequest {
                objective: Objective::MaxPerf { g: gemm_from_json(j)? },
                budget: Budget::evals(j.get("count").as_usize().unwrap_or(64)),
                optimizer: OptimizerKind::DiffAxE,
                top_k: Some(1),
            }),
            "llm_search" => {
                let model_name = j.get("model").as_str().unwrap_or("");
                let model = LlmModel::from_name(model_name)
                    .ok_or_else(|| WireError::bad(format!("unknown model {model_name:?}")))?;
                let stage_name = j.get("stage").as_str().unwrap_or("prefill");
                let stage = Stage::from_name(stage_name)
                    .ok_or_else(|| WireError::bad(format!("unknown stage {stage_name:?}")))?;
                Request::Search(SearchRequest {
                    objective: Objective::LlmEdp {
                        model,
                        stage,
                        seq: DEFAULT_SEQ,
                        platform: Platform::Asic32nm,
                    },
                    budget: Budget::default()
                        .with_per_class(j.get("per_layer").as_usize().unwrap_or(32)),
                    optimizer: OptimizerKind::DiffAxE,
                    top_k: Some(1),
                })
            }
            other => return Err(WireError::bad(format!("unknown request type {other:?}"))),
        })
    }

    /// Encode as the generic current wire form (v1 aliases are parse-only).
    pub fn to_json(&self) -> Json {
        let versioned = |mut fields: Vec<(&'static str, Json)>| {
            fields.insert(0, ("v", Json::Num(PROTOCOL_VERSION as f64)));
            Json::obj(fields)
        };
        let search_typed = |ty: &'static str, s: &SearchRequest| {
            let mut j = versioned(vec![("type", Json::Str(ty.into()))]);
            if let (Json::Obj(o), Json::Obj(inner)) = (&mut j, search_to_json(s)) {
                o.extend(inner);
            }
            j
        };
        match self {
            Request::Search(s) => search_typed("search", s),
            Request::Submit(s) => search_typed("submit", s),
            Request::Batch(items) => versioned(vec![
                ("type", Json::Str("batch".into())),
                ("requests", Json::Arr(items.iter().map(search_to_json).collect())),
            ]),
            Request::Metrics => versioned(vec![("type", Json::Str("metrics".into()))]),
            Request::Status { job_id } => versioned(vec![
                ("type", Json::Str("status".into())),
                ("job_id", Json::Str(job_id.clone())),
            ]),
            Request::Cancel { job_id } => versioned(vec![
                ("type", Json::Str("cancel".into())),
                ("job_id", Json::Str(job_id.clone())),
            ]),
            Request::Jobs => versioned(vec![("type", Json::Str("jobs".into()))]),
            Request::Watch { job_id } => versioned(vec![
                ("type", Json::Str("watch".into())),
                ("job_id", Json::Str(job_id.clone())),
            ]),
        }
    }
}

// ---------------------------------------------------------------------------
// designs / outcomes / responses
// ---------------------------------------------------------------------------

/// The seven configuration fields of one [`HwConfig`] (shared between the
/// design encoding and the per-segment sub-config encoding).
fn hw_fields(hw: &HwConfig) -> Vec<(&'static str, Json)> {
    vec![
        ("r", Json::Num(hw.r as f64)),
        ("c", Json::Num(hw.c as f64)),
        ("ip_kb", Json::Num(hw.ip_kb())),
        ("wt_kb", Json::Num(hw.wt_kb())),
        ("op_kb", Json::Num(hw.op_kb())),
        ("bw", Json::Num(hw.bw as f64)),
        ("loop_order", Json::Str(hw.loop_order.name().into())),
    ]
}

/// Decode one configuration, validating against the target-space
/// parameter ranges (Table II) so malformed peers cannot smuggle nonsense
/// dimensions into downstream consumers.
fn hw_from_json(j: &Json) -> Result<HwConfig> {
    use crate::design_space::params;
    let num = |k: &str| j.get(k).as_f64().with_context(|| format!("design.{k}"));
    let hw = HwConfig {
        r: num("r")? as u32,
        c: num("c")? as u32,
        ip_b: (num("ip_kb")? * 1024.0).round() as u64,
        wt_b: (num("wt_kb")? * 1024.0).round() as u64,
        op_b: (num("op_kb")? * 1024.0).round() as u64,
        bw: num("bw")? as u32,
        loop_order: LoopOrder::from_name(j.get("loop_order").as_str().unwrap_or("mnk"))
            .context("loop_order")?,
    };
    let dim_ok = |d: u32| (params::DIM_MIN..=params::DIM_MAX).contains(&d);
    let buf_ok = |b: u64| (params::BUF_MIN_B..=params::BUF_MAX_B).contains(&b);
    anyhow::ensure!(
        dim_ok(hw.r)
            && dim_ok(hw.c)
            && buf_ok(hw.ip_b)
            && buf_ok(hw.wt_b)
            && buf_ok(hw.op_b)
            && (params::BW_MIN..=params::BW_MAX).contains(&hw.bw),
        "design outside target-space parameter ranges: {hw}"
    );
    Ok(hw)
}

/// JSON encoding of a [`DesignReport`] (implemented here so the DSE layer
/// stays transport-free).
pub fn design_to_json(d: &DesignReport) -> Json {
    let mut fields = hw_fields(&d.hw);
    fields.push(("cycles", Json::Num(d.cycles)));
    fields.push(("power_w", Json::Num(d.power_w)));
    fields.push(("edp", Json::Num(d.edp)));
    Json::obj(fields)
}

/// [`design_to_json`] plus the additive `"segments"` array of a
/// structured design's per-segment sub-configurations and the additive
/// `"boundaries"` array of its learned interior cut points (both omitted
/// when empty, so pre-structured readers — and pre-learned-segmentation
/// readers — see unchanged bytes).
fn design_to_json_with_segments(
    d: &DesignReport,
    segments: Option<&[HwConfig]>,
    boundaries: Option<&[usize]>,
) -> Json {
    let mut fields = hw_fields(&d.hw);
    fields.push(("cycles", Json::Num(d.cycles)));
    fields.push(("power_w", Json::Num(d.power_w)));
    fields.push(("edp", Json::Num(d.edp)));
    if let Some(segs) = segments {
        if !segs.is_empty() {
            fields.push((
                "segments",
                Json::Arr(segs.iter().map(|h| Json::obj(hw_fields(h))).collect()),
            ));
        }
    }
    if let Some(bounds) = boundaries {
        if !bounds.is_empty() {
            fields.push((
                "boundaries",
                Json::Arr(bounds.iter().map(|&b| Json::Num(b as f64)).collect()),
            ));
        }
    }
    Json::obj(fields)
}

/// Decode a [`DesignReport`] (the `"segments"` field, if any, is decoded
/// at the outcome level).
pub fn design_from_json(j: &Json) -> Result<DesignReport> {
    let num = |k: &str| j.get(k).as_f64().with_context(|| format!("design.{k}"));
    Ok(DesignReport {
        hw: hw_from_json(j)?,
        cycles: num("cycles")?,
        power_w: num("power_w")?,
        edp: num("edp")?,
    })
}

fn outcome_fields(o: &SearchOutcome) -> Vec<(&'static str, Json)> {
    let designs = o
        .ranked
        .iter()
        .enumerate()
        .map(|(i, d)| {
            design_to_json_with_segments(
                d,
                o.segments.get(i).map(|s| s.as_slice()),
                o.boundaries.get(i).map(|b| b.as_slice()),
            )
        })
        .collect();
    vec![
        ("optimizer", Json::Str(o.optimizer.clone())),
        ("designs", Json::Arr(designs)),
        ("trace", Json::arr_f64(&o.trace)),
        ("evals", Json::Num(o.evals as f64)),
        ("search_time_s", Json::Num(o.search_time_s)),
        // additive v3 field: v2 readers ignore it (unknown-field tolerance)
        ("stopped", Json::Str(o.stopped.name().into())),
    ]
}

fn outcome_from_json(j: &Json) -> Result<SearchOutcome> {
    let design_objs = j.get("designs").as_arr().context("outcome.designs")?;
    let ranked =
        design_objs.iter().map(design_from_json).collect::<Result<Vec<_>>>()?;
    // additive structured field: per-design segment lists; all-absent
    // normalizes to the empty (non-structured) form
    let mut segments: Vec<Vec<HwConfig>> = Vec::with_capacity(design_objs.len());
    let mut any_segments = false;
    // additive learned-segmentation field: per-design interior cut
    // points, same all-absent normalization as `segments`
    let mut boundaries: Vec<Vec<usize>> = Vec::with_capacity(design_objs.len());
    let mut any_bounds = false;
    for dj in design_objs {
        match dj.get("segments").as_arr() {
            Some(segs) => {
                any_segments = true;
                segments.push(segs.iter().map(hw_from_json).collect::<Result<Vec<_>>>()?);
            }
            None => segments.push(Vec::new()),
        }
        match dj.get("boundaries").as_arr() {
            Some(cuts) => {
                any_bounds = true;
                boundaries.push(
                    cuts.iter()
                        .map(|c| c.as_usize().context("design.boundaries"))
                        .collect::<Result<Vec<_>>>()?,
                );
            }
            None => boundaries.push(Vec::new()),
        }
    }
    let trace = j.get("trace").as_f64_vec().context("outcome.trace")?;
    Ok(SearchOutcome {
        optimizer: j.get("optimizer").as_str().unwrap_or("").to_string(),
        evals: j.get("evals").as_usize().unwrap_or(trace.len()),
        search_time_s: j.get("search_time_s").as_f64().unwrap_or(0.0),
        // absent on pre-v3 peers: those searches always ran to completion
        stopped: j
            .get("stopped")
            .as_str()
            .and_then(StopReason::from_name)
            .unwrap_or(StopReason::Completed),
        segments: if any_segments { segments } else { Vec::new() },
        boundaries: if any_bounds { boundaries } else { Vec::new() },
        ranked,
        trace,
    })
}

/// JSON encoding of a [`SearchEvent`]. `best_score` is omitted while no
/// evaluation has finished (`INFINITY` is not representable in JSON).
fn event_fields(ev: &SearchEvent) -> Vec<(&'static str, Json)> {
    let mut fields = vec![("evals", Json::Num(ev.evals as f64))];
    if ev.best_score.is_finite() {
        fields.push(("best_score", Json::Num(ev.best_score)));
    }
    fields.push(("elapsed_s", Json::Num(ev.elapsed_s)));
    fields
}

fn event_from_json(j: &Json) -> Result<SearchEvent> {
    Ok(SearchEvent {
        evals: j.get("evals").as_usize().context("event.evals")?,
        best_score: j.get("best_score").as_f64().unwrap_or(f64::INFINITY),
        elapsed_s: j.get("elapsed_s").as_f64().unwrap_or(0.0),
    })
}

fn job_info_to_json(i: &JobInfo) -> Json {
    let mut fields = vec![
        ("id", Json::Str(i.id.clone())),
        ("state", Json::Str(i.state.name().into())),
        ("optimizer", Json::Str(i.optimizer.clone())),
        ("objective", Json::Str(i.objective.clone())),
        ("evals", Json::Num(i.evals as f64)),
    ];
    if let Some(b) = i.best_score {
        fields.push(("best_score", Json::Num(b)));
    }
    fields.push(("elapsed_s", Json::Num(i.elapsed_s)));
    // additive: surfaced where it is diagnostic (failures and retries),
    // so pre-PR-8 job lines keep their exact bytes
    if i.attempts > 1 || i.state == JobState::Failed {
        fields.push(("attempts", Json::Num(i.attempts as f64)));
    }
    Json::obj(fields)
}

fn job_info_from_json(j: &Json) -> Result<JobInfo> {
    Ok(JobInfo {
        id: j.get("id").as_str().context("job.id")?.to_string(),
        state: j
            .get("state")
            .as_str()
            .and_then(JobState::from_name)
            .context("job.state")?,
        optimizer: j.get("optimizer").as_str().unwrap_or("").to_string(),
        objective: j.get("objective").as_str().unwrap_or("").to_string(),
        evals: j.get("evals").as_usize().unwrap_or(0),
        best_score: j.get("best_score").as_f64(),
        elapsed_s: j.get("elapsed_s").as_f64().unwrap_or(0.0),
        attempts: j.get("attempts").as_usize().unwrap_or(0) as u32,
    })
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Designs(ds) => Json::obj(vec![
                ("status", Json::Str("ok".into())),
                ("designs", Json::Arr(ds.iter().map(design_to_json).collect())),
            ]),
            Response::Outcome(o) => {
                // carries "designs" too, so v1 readers keep working
                let mut fields = vec![
                    ("status", Json::Str("ok".into())),
                    ("v", Json::Num(PROTOCOL_VERSION as f64)),
                ];
                fields.extend(outcome_fields(o));
                Json::obj(fields)
            }
            Response::Batch(outs) => Json::obj(vec![
                ("status", Json::Str("ok".into())),
                ("v", Json::Num(PROTOCOL_VERSION as f64)),
                ("outcomes", Json::Arr(outs.iter().map(|o| Json::obj(outcome_fields(o))).collect())),
            ]),
            Response::MetricsText(s) => Json::obj(vec![
                ("status", Json::Str("ok".into())),
                ("metrics", Json::Str(s.clone())),
            ]),
            Response::Submitted { job_id, state } => Json::obj(vec![
                ("status", Json::Str("ok".into())),
                ("v", Json::Num(PROTOCOL_VERSION as f64)),
                ("job_id", Json::Str(job_id.clone())),
                ("job_state", Json::Str(state.name().into())),
            ]),
            Response::Job(info) => Json::obj(vec![
                ("status", Json::Str("ok".into())),
                ("v", Json::Num(PROTOCOL_VERSION as f64)),
                ("job", job_info_to_json(info)),
            ]),
            Response::Jobs(infos) => Json::obj(vec![
                ("status", Json::Str("ok".into())),
                ("v", Json::Num(PROTOCOL_VERSION as f64)),
                ("jobs", Json::Arr(infos.iter().map(job_info_to_json).collect())),
            ]),
            Response::Event { job_id, event } => {
                let mut fields = vec![
                    ("status", Json::Str("ok".into())),
                    ("v", Json::Num(PROTOCOL_VERSION as f64)),
                    ("type", Json::Str("event".into())),
                    ("job_id", Json::Str(job_id.clone())),
                ];
                fields.extend(event_fields(event));
                Json::obj(fields)
            }
            Response::JobOutcome { job_id, outcome } => {
                let mut fields = vec![
                    ("status", Json::Str("ok".into())),
                    ("v", Json::Num(PROTOCOL_VERSION as f64)),
                    ("type", Json::Str("outcome".into())),
                    ("job_id", Json::Str(job_id.clone())),
                ];
                fields.extend(outcome_fields(outcome));
                Json::obj(fields)
            }
            Response::Error { code, message, retry_after_ms } => {
                let mut fields = vec![
                    ("status", Json::Str("error".into())),
                    ("v", Json::Num(PROTOCOL_VERSION as f64)),
                    ("code", Json::Str(code.name().into())),
                    ("message", Json::Str(message.clone())),
                ];
                if let Some(ms) = retry_after_ms {
                    fields.push(("retry_after_ms", Json::Num(*ms as f64)));
                }
                Json::obj(fields)
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<Response> {
        match j.get("status").as_str() {
            Some("ok") => {
                // stream lines carry an explicit discriminator
                if let Some(ty) = j.get("type").as_str() {
                    let job_id = j.get("job_id").as_str().context("job_id")?.to_string();
                    return match ty {
                        "event" => Ok(Response::Event { job_id, event: event_from_json(j)? }),
                        "outcome" => {
                            Ok(Response::JobOutcome { job_id, outcome: outcome_from_json(j)? })
                        }
                        other => bail!("unknown stream line type {other:?}"),
                    };
                }
                if let Some(m) = j.get("metrics").as_str() {
                    Ok(Response::MetricsText(m.to_string()))
                } else if !matches!(j.get("job"), Json::Null) {
                    Ok(Response::Job(job_info_from_json(j.get("job"))?))
                } else if let Some(jobs) = j.get("jobs").as_arr() {
                    Ok(Response::Jobs(
                        jobs.iter().map(job_info_from_json).collect::<Result<Vec<_>>>()?,
                    ))
                } else if let Some(id) = j.get("job_id").as_str() {
                    Ok(Response::Submitted {
                        job_id: id.to_string(),
                        state: j
                            .get("job_state")
                            .as_str()
                            .and_then(JobState::from_name)
                            .unwrap_or(JobState::Queued),
                    })
                } else if let Some(outs) = j.get("outcomes").as_arr() {
                    Ok(Response::Batch(
                        outs.iter().map(outcome_from_json).collect::<Result<Vec<_>>>()?,
                    ))
                } else if !matches!(j.get("trace"), Json::Null) {
                    Ok(Response::Outcome(outcome_from_json(j)?))
                } else {
                    let ds = j
                        .get("designs")
                        .as_arr()
                        .context("designs")?
                        .iter()
                        .map(design_from_json)
                        .collect::<Result<Vec<_>>>()?;
                    Ok(Response::Designs(ds))
                }
            }
            Some("error") => Ok(Response::Error {
                code: j
                    .get("code")
                    .as_str()
                    .and_then(ErrorCode::from_name)
                    .unwrap_or(ErrorCode::Internal),
                message: j.get("message").as_str().unwrap_or("").to_string(),
                retry_after_ms: j.get("retry_after_ms").as_usize().map(|ms| ms as u64),
            }),
            _ => bail!("bad response"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::{HwConfig, LoopOrder};

    fn parse(s: &str) -> Result<Request, WireError> {
        Request::from_json(&Json::parse(s).unwrap())
    }

    #[test]
    fn generic_request_roundtrip() {
        let reqs = vec![
            Request::Search(SearchRequest::new(
                Objective::Runtime { g: Gemm::new(128, 768, 768), target_cycles: 1e6 },
                Budget::evals(32),
                OptimizerKind::DiffAxE,
            )),
            Request::Search(SearchRequest {
                objective: Objective::MinEdp { g: Gemm::new(1, 2, 3) },
                budget: Budget::evals(90).with_per_class(5).with_wall_clock(1.5),
                optimizer: OptimizerKind::VanillaBo,
                top_k: Some(3),
            }),
            Request::Search(SearchRequest::new(
                Objective::LlmEdp {
                    model: LlmModel::BertBase,
                    stage: Stage::Decode,
                    seq: 64,
                    platform: Platform::FpgaVu13p,
                },
                Budget::default().with_per_class(4),
                OptimizerKind::DosaGd,
            )),
            Request::Batch(vec![
                SearchRequest::new(
                    Objective::MaxPerf { g: Gemm::new(9, 9, 9) },
                    Budget::evals(7),
                    OptimizerKind::RandomSearch,
                ),
                SearchRequest::new(
                    Objective::MinEdp { g: Gemm::new(4, 5, 6) },
                    Budget::evals(8),
                    OptimizerKind::Fixed(crate::baselines::FixedArch::Nvdla),
                ),
            ]),
            Request::Metrics,
        ];
        for r in reqs {
            let j = Json::parse(&r.to_json().to_string()).unwrap();
            assert_eq!(Request::from_json(&j).unwrap(), r, "{r:?}");
        }
    }

    #[test]
    fn legacy_aliases_still_parse() {
        let r = parse(r#"{"type":"generate","m":128,"k":768,"n":2304,"target_cycles":1e6,"count":8}"#)
            .unwrap();
        assert_eq!(
            r,
            Request::Search(SearchRequest {
                objective: Objective::Runtime { g: Gemm::new(128, 768, 2304), target_cycles: 1e6 },
                budget: Budget::evals(8),
                optimizer: OptimizerKind::DiffAxE,
                top_k: Some(8), // v1 `generate` returned `count` designs
            })
        );
        let r = parse(r#"{"type":"edp_search","m":1,"k":2,"n":3,"per_class":5}"#).unwrap();
        assert_eq!(
            r,
            Request::Search(SearchRequest {
                objective: Objective::MinEdp { g: Gemm::new(1, 2, 3) },
                budget: Budget::default().with_per_class(5),
                optimizer: OptimizerKind::DiffAxE,
                top_k: Some(1), // v1 `edp_search` returned the single best
            })
        );
        let r = parse(r#"{"type":"perf_search","m":9,"k":9,"n":9,"count":7}"#).unwrap();
        assert!(matches!(
            r,
            Request::Search(SearchRequest {
                objective: Objective::MaxPerf { .. },
                optimizer: OptimizerKind::DiffAxE,
                ..
            })
        ));
        let r = parse(r#"{"type":"llm_search","model":"bert-base","stage":"decode","per_layer":4}"#)
            .unwrap();
        assert_eq!(
            r,
            Request::Search(SearchRequest {
                objective: Objective::LlmEdp {
                    model: LlmModel::BertBase,
                    stage: Stage::Decode,
                    seq: DEFAULT_SEQ,
                    platform: Platform::Asic32nm,
                },
                budget: Budget::default().with_per_class(4),
                optimizer: OptimizerKind::DiffAxE,
                top_k: Some(1),
            })
        );
    }

    #[test]
    fn unknown_fields_are_ignored() {
        let r = parse(
            r#"{"v":2,"type":"search","some_future_flag":true,"nested":{"x":1},
                "objective":{"kind":"min_edp","m":4,"k":5,"n":6,"hint":"fast"},
                "budget":{"evals":12,"gpu_hours":99},"optimizer":"random"}"#,
        )
        .unwrap();
        assert_eq!(
            r,
            Request::Search(SearchRequest::new(
                Objective::MinEdp { g: Gemm::new(4, 5, 6) },
                Budget::evals(12),
                OptimizerKind::RandomSearch,
            ))
        );
        // legacy form with extra fields parses too
        assert!(parse(r#"{"type":"metrics","extra":[1,2,3]}"#).is_ok());
    }

    #[test]
    fn version_mismatch_is_a_structured_error() {
        let err = parse(r#"{"v":4,"type":"search"}"#).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnsupportedVersion);
        // and it serializes into an error *response*, not a hangup
        let resp = Response::error(err.code, err.message);
        let j = Json::parse(&resp.to_json().to_string()).unwrap();
        match Response::from_json(&j).unwrap() {
            Response::Error { code, message, retry_after_ms } => {
                assert_eq!(code, ErrorCode::UnsupportedVersion);
                assert!(message.contains("v4"));
                assert_eq!(retry_after_ms, None);
            }
            other => panic!("unexpected {other:?}"),
        }
        // requests at or below the supported version are fine
        assert!(parse(r#"{"v":2,"type":"metrics"}"#).is_ok());
        assert!(parse(r#"{"v":3,"type":"jobs"}"#).is_ok());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse(r#"{"type":"nope"}"#).is_err());
        assert!(parse(r#"{"type":"generate","m":1}"#).is_err());
        assert!(parse(r#"{"type":"search","objective":{"kind":"warp"}}"#).is_err());
        assert!(parse(r#"{"type":"batch","requests":[]}"#).is_err());
        // zero GEMM dims must not panic the connection thread
        let err =
            parse(r#"{"type":"generate","m":0,"k":1,"n":1,"target_cycles":1.0}"#).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        // unknown optimizer name
        let err = parse(
            r#"{"type":"search","objective":{"kind":"min_edp","m":1,"k":1,"n":1},
                "optimizer":"sgd"}"#,
        )
        .unwrap_err();
        assert!(err.message.contains("sgd"));
    }

    #[test]
    fn response_roundtrip() {
        let d = DesignReport {
            hw: HwConfig::new_kb(16, 32, 64.0, 128.0, 8.5, 12, LoopOrder::Nmk),
            cycles: 12345.0,
            power_w: 1.25,
            edp: 3.4e8,
        };
        let outcome = SearchOutcome {
            optimizer: "DiffAxE".into(),
            ranked: vec![d],
            trace: vec![0.25],
            evals: 1,
            search_time_s: 0.5,
            segments: Vec::new(),
            boundaries: Vec::new(),
            stopped: StopReason::Completed,
        };
        let partial = SearchOutcome { stopped: StopReason::Cancelled, ..outcome.clone() };
        let info = JobInfo {
            id: "job-3".into(),
            state: JobState::Running,
            optimizer: "dosa-gd".into(),
            objective: "min-EDP 128x768x768".into(),
            evals: 40,
            best_score: Some(1.5e9),
            elapsed_s: 0.7,
            // retried once: attempts is surfaced on the wire
            attempts: 2,
        };
        let info_fresh = JobInfo {
            state: JobState::Queued,
            evals: 0,
            best_score: None,
            attempts: 0,
            ..info.clone()
        };
        let info_failed = JobInfo {
            state: JobState::Failed,
            evals: 3,
            best_score: None,
            attempts: 1,
            ..info.clone()
        };
        for resp in [
            Response::Designs(vec![d]),
            Response::Outcome(outcome.clone()),
            Response::Batch(vec![outcome.clone(), partial.clone()]),
            Response::MetricsText("requests=1".into()),
            Response::Submitted { job_id: "job-1".into(), state: JobState::Queued },
            Response::Job(info.clone()),
            Response::Jobs(vec![info, info_fresh, info_failed]),
            Response::Event {
                job_id: "job-2".into(),
                event: SearchEvent { evals: 64, best_score: 0.125, elapsed_s: 1.5 },
            },
            // pre-first-evaluation event: infinite best is omitted on the wire
            Response::Event {
                job_id: "job-2".into(),
                event: SearchEvent { evals: 0, best_score: f64::INFINITY, elapsed_s: 0.0 },
            },
            Response::JobOutcome { job_id: "job-2".into(), outcome: partial },
            Response::error(ErrorCode::Internal, "boom"),
            Response::overloaded("queue full: 8 jobs queued (max 8)", 120),
        ] {
            let j = Json::parse(&resp.to_json().to_string()).unwrap();
            assert_eq!(Response::from_json(&j).unwrap(), resp);
        }
    }

    #[test]
    fn v3_request_roundtrip() {
        let sr = SearchRequest::new(
            Objective::MinEdp { g: Gemm::new(4, 5, 6) },
            Budget::evals(1000).with_wall_clock(0.25),
            OptimizerKind::DosaGd,
        );
        for r in [
            Request::Submit(sr),
            Request::Status { job_id: "job-9".into() },
            Request::Cancel { job_id: "job-9".into() },
            Request::Jobs,
            Request::Watch { job_id: "job-9".into() },
        ] {
            let j = Json::parse(&r.to_json().to_string()).unwrap();
            assert_eq!(Request::from_json(&j).unwrap(), r, "{r:?}");
        }
        // job_id is mandatory on the job-addressed forms
        for line in [
            r#"{"v":3,"type":"status"}"#,
            r#"{"v":3,"type":"cancel"}"#,
            r#"{"v":3,"type":"watch"}"#,
        ] {
            let err = parse(line).unwrap_err();
            assert_eq!(err.code, ErrorCode::BadRequest, "{line}");
            assert!(err.message.contains("job_id"));
        }
    }

    #[test]
    fn v3_unknown_fields_are_ignored() {
        let r = parse(
            r#"{"v":3,"type":"submit","priority":"high",
                "objective":{"kind":"max_perf","m":7,"k":8,"n":9},
                "budget":{"evals":5,"wall_clock_s":0.5},"optimizer":"random"}"#,
        )
        .unwrap();
        assert_eq!(
            r,
            Request::Submit(SearchRequest::new(
                Objective::MaxPerf { g: Gemm::new(7, 8, 9) },
                Budget::evals(5).with_wall_clock(0.5),
                OptimizerKind::RandomSearch,
            ))
        );
        assert!(parse(r#"{"v":3,"type":"jobs","verbose":true}"#).is_ok());
    }

    #[test]
    fn structured_objective_roundtrip_and_validation() {
        use crate::dse::structured::StructuredSpec;
        let spec = StructuredSpec {
            model: LlmModel::BertBase,
            stage: Stage::Prefill,
            seq: 128,
            platform: Platform::Asic32nm,
            segments: 3,
            budget: SharedBudget { pe: 4096, buf_b: 768 * 1024, bw: 16 },
        };
        for obj in [Objective::StructuredEdp { spec }, Objective::StructuredPerf { spec }] {
            let r = Request::Search(SearchRequest::new(
                obj,
                Budget::evals(32),
                OptimizerKind::DosaGd,
            ));
            let j = Json::parse(&r.to_json().to_string()).unwrap();
            assert_eq!(Request::from_json(&j).unwrap(), r, "{obj}");
        }
        // absent budget/segments fall back to defaults
        let r = parse(
            r#"{"v":3,"type":"search","optimizer":"random",
                "objective":{"kind":"structured_edp","model":"bert-base"}}"#,
        )
        .unwrap();
        match r {
            Request::Search(SearchRequest {
                objective: Objective::StructuredEdp { spec }, ..
            }) => {
                assert_eq!(spec.segments, 3);
                assert_eq!(spec.budget, SharedBudget::default());
                assert_eq!(spec.seq, DEFAULT_SEQ);
            }
            other => panic!("unexpected {other:?}"),
        }
        // an impossible budget is a bad request, not a server panic
        let err = parse(
            r#"{"type":"search","objective":{"kind":"structured_edp",
                "model":"bert-base","budget":{"pe":1}},"optimizer":"random"}"#,
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        // and so is a zero segment count
        let err = parse(
            r#"{"type":"search","objective":{"kind":"structured_perf",
                "model":"bert-base","segments":0},"optimizer":"random"}"#,
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        // an over-u32 value is rejected, never silently wrapped into a
        // valid-looking spec
        let err = parse(
            r#"{"type":"search","objective":{"kind":"structured_edp",
                "model":"bert-base","segments":4294967299},"optimizer":"random"}"#,
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("segments"), "{}", err.message);
    }

    #[test]
    fn structured_outcome_roundtrip_carries_segments() {
        let seg_a = HwConfig::new_kb(64, 64, 256.0, 128.0, 32.0, 16, LoopOrder::Mnk);
        let seg_b = HwConfig::new_kb(16, 128, 64.0, 512.0, 16.0, 16, LoopOrder::Nmk);
        let d = DesignReport {
            hw: HwConfig::new_kb(64, 128, 256.0, 512.0, 32.0, 16, LoopOrder::Mnk),
            cycles: 1024.0,
            power_w: 2.5,
            edp: 4096.0,
        };
        let outcome = SearchOutcome {
            optimizer: "DiffAxE".into(),
            ranked: vec![d],
            trace: vec![4096.0],
            evals: 1,
            search_time_s: 0.5,
            segments: vec![vec![seg_a, seg_b]],
            boundaries: vec![vec![3]],
            stopped: StopReason::Completed,
        };
        for resp in [
            Response::Outcome(outcome.clone()),
            Response::JobOutcome { job_id: "job-7".into(), outcome },
        ] {
            let j = Json::parse(&resp.to_json().to_string()).unwrap();
            assert_eq!(Response::from_json(&j).unwrap(), resp);
        }
        // a non-structured outcome's designs carry no "segments" (and no
        // "boundaries") key at all
        let plain = SearchOutcome {
            optimizer: "Random Search".into(),
            ranked: vec![d],
            trace: vec![4096.0],
            evals: 1,
            search_time_s: 0.0,
            segments: Vec::new(),
            boundaries: Vec::new(),
            stopped: StopReason::Completed,
        };
        let j = Response::Outcome(plain).to_json();
        assert!(matches!(j.get("designs").as_arr().unwrap()[0].get("segments"), Json::Null));
        assert!(matches!(j.get("designs").as_arr().unwrap()[0].get("boundaries"), Json::Null));
    }

    #[test]
    fn fixed_partition_structured_outcome_carries_no_boundaries_key() {
        // learned cuts are additive: a fixed-partition structured outcome
        // (empty `boundaries`) serializes byte-identically to pre-learned
        // peers — its designs carry "segments" but never "boundaries"
        let seg = HwConfig::new_kb(64, 64, 256.0, 128.0, 32.0, 16, LoopOrder::Mnk);
        let d = DesignReport {
            hw: HwConfig::new_kb(64, 128, 256.0, 512.0, 32.0, 16, LoopOrder::Mnk),
            cycles: 1024.0,
            power_w: 2.5,
            edp: 4096.0,
        };
        let out = SearchOutcome {
            optimizer: "DiffAxE".into(),
            ranked: vec![d],
            trace: vec![4096.0],
            evals: 1,
            search_time_s: 0.5,
            segments: vec![vec![seg, seg]],
            boundaries: Vec::new(),
            stopped: StopReason::Completed,
        };
        let j = Response::Outcome(out.clone()).to_json();
        let dj = &j.get("designs").as_arr().unwrap()[0];
        assert!(!matches!(dj.get("segments"), Json::Null));
        assert!(matches!(dj.get("boundaries"), Json::Null));
        // and it still roundtrips
        let back = Response::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, Response::Outcome(out));
    }

    #[test]
    fn outcome_without_stopped_field_decodes_as_completed() {
        // a pre-v3 peer's outcome line has no "stopped": tolerate it
        let line = r#"{"status":"ok","v":2,"optimizer":"Random Search",
            "designs":[],"trace":[],"evals":0,"search_time_s":0.1}"#;
        match Response::from_json(&Json::parse(line).unwrap()).unwrap() {
            Response::Outcome(o) => assert_eq!(o.stopped, StopReason::Completed),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn outcome_response_is_v1_readable() {
        // a v1 client reads "designs" from a v2 Outcome response
        let d = DesignReport {
            hw: HwConfig::new_kb(8, 8, 64.0, 64.0, 16.0, 8, LoopOrder::Mnk),
            cycles: 10.0,
            power_w: 0.5,
            edp: 5.0,
        };
        let out = SearchOutcome {
            optimizer: "Random Search".into(),
            ranked: vec![d],
            trace: vec![5.0],
            evals: 1,
            search_time_s: 0.0,
            segments: Vec::new(),
            boundaries: Vec::new(),
            stopped: StopReason::Completed,
        };
        let j = Response::Outcome(out).to_json();
        let designs = j.get("designs").as_arr().unwrap();
        assert_eq!(designs.len(), 1);
        assert_eq!(design_from_json(&designs[0]).unwrap(), d);
    }

    #[test]
    fn design_validation_rejects_out_of_range() {
        let d = DesignReport {
            hw: HwConfig::new_kb(16, 32, 64.0, 128.0, 8.5, 12, LoopOrder::Nmk),
            cycles: 1.0,
            power_w: 1.0,
            edp: 1.0,
        };
        let mut j = design_to_json(&d);
        if let Json::Obj(o) = &mut j {
            o.insert("r".into(), Json::Num(100000.0));
        }
        assert!(design_from_json(&j).is_err());
    }
}
