//! Request/response types and their JSON wire encoding (newline-delimited
//! JSON over TCP — see [`super::server`]).
//!
//! # Versioning
//!
//! Every message may carry a `"v"` field. Requests without one are treated
//! as protocol v1 (the original four hardcoded request forms, kept as
//! deprecated parse-only aliases); `"v"` above [`PROTOCOL_VERSION`] yields
//! a structured [`Response::Error`] with code `unsupported_version` rather
//! than a dropped connection. Unknown JSON fields are ignored everywhere,
//! so additive evolution never breaks old peers.
//!
//! # v2 request forms
//!
//! One generic search request replaces the per-task variants — any
//! [`Objective`] × [`Budget`] × [`OptimizerKind`]:
//!
//! ```json
//! {"v":2,"type":"search",
//!  "objective":{"kind":"runtime","m":128,"k":768,"n":2304,"target_cycles":1e6},
//!  "budget":{"evals":16},
//!  "optimizer":"diffaxe"}
//! ```
//!
//! and a `batch` request carries several searches in one round-trip:
//!
//! ```json
//! {"v":2,"type":"batch","requests":[{"objective":…,"budget":…,"optimizer":…},…]}
//! ```
//!
//! Batch semantics: every item is validated before any runs (a detectably
//! bad pairing answers `bad_request` up front); execution is then
//! all-or-nothing — a mid-batch internal failure answers a single
//! `internal` error rather than a partial outcome list.

use crate::dse::api::{Budget, DesignReport, Objective, OptimizerKind, SearchOutcome};
use crate::dse::llm::Platform;
use crate::util::json::Json;
use crate::workload::{llm::DEFAULT_SEQ, Gemm, LlmModel, Stage};
use anyhow::{bail, Context, Result};

/// Highest protocol version this build speaks.
pub const PROTOCOL_VERSION: u32 = 2;

/// Structured wire-error categories.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// malformed or semantically invalid request
    BadRequest,
    /// request's `"v"` is newer than [`PROTOCOL_VERSION`]
    UnsupportedVersion,
    /// the request was valid but serving it failed
    Internal,
}

impl ErrorCode {
    pub fn name(&self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnsupportedVersion => "unsupported_version",
            ErrorCode::Internal => "internal",
        }
    }

    pub fn from_name(s: &str) -> Option<ErrorCode> {
        [ErrorCode::BadRequest, ErrorCode::UnsupportedVersion, ErrorCode::Internal]
            .into_iter()
            .find(|c| c.name() == s)
    }
}

/// A request that could not be decoded, with its error category — the
/// server turns this into a [`Response::Error`] on the same connection.
#[derive(Debug, Clone, PartialEq)]
pub struct WireError {
    pub code: ErrorCode,
    pub message: String,
}

impl WireError {
    fn bad(message: impl Into<String>) -> WireError {
        WireError { code: ErrorCode::BadRequest, message: message.into() }
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code.name(), self.message)
    }
}

impl std::error::Error for WireError {}

/// One search: what to optimize, how much to spend, and with which
/// strategy.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchRequest {
    pub objective: Objective,
    pub budget: Budget,
    pub optimizer: OptimizerKind,
    /// cap on ranked designs in the response (`None` = server default)
    pub top_k: Option<usize>,
}

impl SearchRequest {
    pub fn new(objective: Objective, budget: Budget, optimizer: OptimizerKind) -> SearchRequest {
        SearchRequest { objective, budget, optimizer, top_k: None }
    }
}

/// A DSE request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// one generic search
    Search(SearchRequest),
    /// several searches served in one round-trip
    Batch(Vec<SearchRequest>),
    /// service introspection
    Metrics,
}

/// A DSE response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// protocol-v1 result shape (parse compatibility; v2 serves `Outcome`)
    Designs(Vec<DesignReport>),
    /// one search's full outcome (ranked designs + trace + accounting)
    Outcome(SearchOutcome),
    /// outcomes of a `Batch` request, in request order
    Batch(Vec<SearchOutcome>),
    MetricsText(String),
    Error { code: ErrorCode, message: String },
}

impl Response {
    pub fn error(code: ErrorCode, message: impl Into<String>) -> Response {
        Response::Error { code, message: message.into() }
    }
}

// ---------------------------------------------------------------------------
// objective / budget encoding
// ---------------------------------------------------------------------------

fn gemm_from_json(j: &Json) -> Result<Gemm, WireError> {
    let dim = |k: &str| -> Result<u32, WireError> {
        let v = j.get(k).as_usize().ok_or_else(|| WireError::bad(format!("missing '{k}'")))?;
        if v < 1 || v > u32::MAX as usize {
            return Err(WireError::bad(format!("'{k}' out of range: {v}")));
        }
        Ok(v as u32)
    };
    Ok(Gemm::new(dim("m")?, dim("k")?, dim("n")?))
}

fn gemm_fields(g: &Gemm) -> Vec<(&'static str, Json)> {
    vec![
        ("m", Json::Num(g.m as f64)),
        ("k", Json::Num(g.k as f64)),
        ("n", Json::Num(g.n as f64)),
    ]
}

fn objective_to_json(o: &Objective) -> Json {
    match o {
        Objective::Runtime { g, target_cycles } => {
            let mut fields = vec![("kind", Json::Str("runtime".into()))];
            fields.extend(gemm_fields(g));
            fields.push(("target_cycles", Json::Num(*target_cycles)));
            Json::obj(fields)
        }
        Objective::MinEdp { g } => {
            let mut fields = vec![("kind", Json::Str("min_edp".into()))];
            fields.extend(gemm_fields(g));
            Json::obj(fields)
        }
        Objective::MaxPerf { g } => {
            let mut fields = vec![("kind", Json::Str("max_perf".into()))];
            fields.extend(gemm_fields(g));
            Json::obj(fields)
        }
        Objective::LlmEdp { model, stage, seq, platform } => Json::obj(vec![
            ("kind", Json::Str("llm_edp".into())),
            ("model", Json::Str(model.wire_name().into())),
            ("stage", Json::Str(stage.name().into())),
            ("seq", Json::Num(*seq as f64)),
            ("platform", Json::Str(platform.name().into())),
        ]),
    }
}

fn objective_from_json(j: &Json) -> Result<Objective, WireError> {
    let kind = j
        .get("kind")
        .as_str()
        .ok_or_else(|| WireError::bad("objective missing 'kind'"))?;
    Ok(match kind {
        "runtime" => Objective::Runtime {
            g: gemm_from_json(j)?,
            target_cycles: j
                .get("target_cycles")
                .as_f64()
                .ok_or_else(|| WireError::bad("missing 'target_cycles'"))?,
        },
        "min_edp" => Objective::MinEdp { g: gemm_from_json(j)? },
        "max_perf" => Objective::MaxPerf { g: gemm_from_json(j)? },
        "llm_edp" => {
            let model_name = j.get("model").as_str().unwrap_or("");
            let model = LlmModel::from_name(model_name)
                .ok_or_else(|| WireError::bad(format!("unknown model {model_name:?}")))?;
            let stage_name = j.get("stage").as_str().unwrap_or("prefill");
            let stage = Stage::from_name(stage_name)
                .ok_or_else(|| WireError::bad(format!("unknown stage {stage_name:?}")))?;
            let platform_name = j.get("platform").as_str().unwrap_or("asic-32nm");
            let platform = Platform::from_name(platform_name)
                .ok_or_else(|| WireError::bad(format!("unknown platform {platform_name:?}")))?;
            let seq = j.get("seq").as_usize().unwrap_or(DEFAULT_SEQ as usize) as u32;
            Objective::LlmEdp { model, stage, seq, platform }
        }
        other => return Err(WireError::bad(format!("unknown objective kind {other:?}"))),
    })
}

fn budget_to_json(b: &Budget) -> Json {
    let mut fields = vec![("evals", Json::Num(b.evals as f64))];
    if let Some(pc) = b.per_class {
        fields.push(("per_class", Json::Num(pc as f64)));
    }
    if let Some(w) = b.wall_clock_s {
        fields.push(("wall_clock_s", Json::Num(w)));
    }
    Json::obj(fields)
}

fn budget_from_json(j: &Json) -> Result<Budget, WireError> {
    if matches!(j, Json::Null) {
        return Ok(Budget::default());
    }
    let mut b = Budget::default();
    if let Some(n) = j.get("evals").as_usize() {
        b.evals = n;
    }
    b.per_class = j.get("per_class").as_usize();
    b.wall_clock_s = j.get("wall_clock_s").as_f64();
    Ok(b)
}

fn search_from_json(j: &Json) -> Result<SearchRequest, WireError> {
    let objective = objective_from_json(j.get("objective"))?;
    let budget = budget_from_json(j.get("budget"))?;
    let opt_name = j.get("optimizer").as_str().unwrap_or("diffaxe");
    let optimizer = OptimizerKind::parse(opt_name)
        .ok_or_else(|| WireError::bad(format!("unknown optimizer {opt_name:?}")))?;
    Ok(SearchRequest { objective, budget, optimizer, top_k: j.get("top_k").as_usize() })
}

fn search_to_json(s: &SearchRequest) -> Json {
    let mut fields = vec![
        ("objective", objective_to_json(&s.objective)),
        ("budget", budget_to_json(&s.budget)),
        ("optimizer", Json::Str(s.optimizer.name().into())),
    ];
    if let Some(k) = s.top_k {
        fields.push(("top_k", Json::Num(k as f64)));
    }
    Json::obj(fields)
}

// ---------------------------------------------------------------------------
// requests
// ---------------------------------------------------------------------------

impl Request {
    /// Decode a request. Accepts the generic v2 forms and the deprecated
    /// v1 aliases (`generate`, `edp_search`, `perf_search`, `llm_search`),
    /// which parse into the equivalent [`SearchRequest`] with the
    /// `diffaxe` optimizer.
    pub fn from_json(j: &Json) -> Result<Request, WireError> {
        if let Some(v) = j.get("v").as_f64() {
            if v > PROTOCOL_VERSION as f64 {
                return Err(WireError {
                    code: ErrorCode::UnsupportedVersion,
                    message: format!("request v{v} exceeds supported v{PROTOCOL_VERSION}"),
                });
            }
        }
        let ty = j
            .get("type")
            .as_str()
            .ok_or_else(|| WireError::bad("request missing 'type'"))?;
        Ok(match ty {
            "search" => Request::Search(search_from_json(j)?),
            "batch" => {
                let items = j
                    .get("requests")
                    .as_arr()
                    .ok_or_else(|| WireError::bad("batch missing 'requests'"))?;
                if items.is_empty() {
                    return Err(WireError::bad("batch must carry at least one search"));
                }
                Request::Batch(items.iter().map(search_from_json).collect::<Result<_, _>>()?)
            }
            "metrics" => Request::Metrics,
            // ---- deprecated v1 aliases ------------------------------------
            // each alias pins `top_k` to its v1 response shape: `generate`
            // returned `count` designs, the three searches their single best
            "generate" => {
                let count = j.get("count").as_usize().unwrap_or(16);
                Request::Search(SearchRequest {
                    objective: Objective::Runtime {
                        g: gemm_from_json(j)?,
                        target_cycles: j
                            .get("target_cycles")
                            .as_f64()
                            .ok_or_else(|| WireError::bad("missing 'target_cycles'"))?,
                    },
                    budget: Budget::evals(count),
                    optimizer: OptimizerKind::DiffAxE,
                    top_k: Some(count),
                })
            }
            "edp_search" => Request::Search(SearchRequest {
                objective: Objective::MinEdp { g: gemm_from_json(j)? },
                budget: Budget::default()
                    .with_per_class(j.get("per_class").as_usize().unwrap_or(32)),
                optimizer: OptimizerKind::DiffAxE,
                top_k: Some(1),
            }),
            "perf_search" => Request::Search(SearchRequest {
                objective: Objective::MaxPerf { g: gemm_from_json(j)? },
                budget: Budget::evals(j.get("count").as_usize().unwrap_or(64)),
                optimizer: OptimizerKind::DiffAxE,
                top_k: Some(1),
            }),
            "llm_search" => {
                let model_name = j.get("model").as_str().unwrap_or("");
                let model = LlmModel::from_name(model_name)
                    .ok_or_else(|| WireError::bad(format!("unknown model {model_name:?}")))?;
                let stage_name = j.get("stage").as_str().unwrap_or("prefill");
                let stage = Stage::from_name(stage_name)
                    .ok_or_else(|| WireError::bad(format!("unknown stage {stage_name:?}")))?;
                Request::Search(SearchRequest {
                    objective: Objective::LlmEdp {
                        model,
                        stage,
                        seq: DEFAULT_SEQ,
                        platform: Platform::Asic32nm,
                    },
                    budget: Budget::default()
                        .with_per_class(j.get("per_layer").as_usize().unwrap_or(32)),
                    optimizer: OptimizerKind::DiffAxE,
                    top_k: Some(1),
                })
            }
            other => return Err(WireError::bad(format!("unknown request type {other:?}"))),
        })
    }

    /// Encode as the generic v2 wire form (v1 aliases are parse-only).
    pub fn to_json(&self) -> Json {
        let versioned = |mut fields: Vec<(&'static str, Json)>| {
            fields.insert(0, ("v", Json::Num(PROTOCOL_VERSION as f64)));
            Json::obj(fields)
        };
        match self {
            Request::Search(s) => {
                let mut j = versioned(vec![("type", Json::Str("search".into()))]);
                if let (Json::Obj(o), Json::Obj(inner)) = (&mut j, search_to_json(s)) {
                    o.extend(inner);
                }
                j
            }
            Request::Batch(items) => versioned(vec![
                ("type", Json::Str("batch".into())),
                ("requests", Json::Arr(items.iter().map(search_to_json).collect())),
            ]),
            Request::Metrics => versioned(vec![("type", Json::Str("metrics".into()))]),
        }
    }
}

// ---------------------------------------------------------------------------
// designs / outcomes / responses
// ---------------------------------------------------------------------------

/// JSON encoding of a [`DesignReport`] (implemented here so the DSE layer
/// stays transport-free).
pub fn design_to_json(d: &DesignReport) -> Json {
    Json::obj(vec![
        ("r", Json::Num(d.hw.r as f64)),
        ("c", Json::Num(d.hw.c as f64)),
        ("ip_kb", Json::Num(d.hw.ip_kb())),
        ("wt_kb", Json::Num(d.hw.wt_kb())),
        ("op_kb", Json::Num(d.hw.op_kb())),
        ("bw", Json::Num(d.hw.bw as f64)),
        ("loop_order", Json::Str(d.hw.loop_order.name().into())),
        ("cycles", Json::Num(d.cycles)),
        ("power_w", Json::Num(d.power_w)),
        ("edp", Json::Num(d.edp)),
    ])
}

/// Decode a [`DesignReport`], validating the configuration against the
/// target-space parameter ranges (Table II) so malformed peers cannot
/// smuggle nonsense dimensions into downstream consumers.
pub fn design_from_json(j: &Json) -> Result<DesignReport> {
    use crate::design_space::{params, HwConfig, LoopOrder};
    let num = |k: &str| j.get(k).as_f64().with_context(|| format!("design.{k}"));
    let hw = HwConfig {
        r: num("r")? as u32,
        c: num("c")? as u32,
        ip_b: (num("ip_kb")? * 1024.0).round() as u64,
        wt_b: (num("wt_kb")? * 1024.0).round() as u64,
        op_b: (num("op_kb")? * 1024.0).round() as u64,
        bw: num("bw")? as u32,
        loop_order: LoopOrder::from_name(j.get("loop_order").as_str().unwrap_or("mnk"))
            .context("loop_order")?,
    };
    let dim_ok = |d: u32| (params::DIM_MIN..=params::DIM_MAX).contains(&d);
    let buf_ok = |b: u64| (params::BUF_MIN_B..=params::BUF_MAX_B).contains(&b);
    anyhow::ensure!(
        dim_ok(hw.r)
            && dim_ok(hw.c)
            && buf_ok(hw.ip_b)
            && buf_ok(hw.wt_b)
            && buf_ok(hw.op_b)
            && (params::BW_MIN..=params::BW_MAX).contains(&hw.bw),
        "design outside target-space parameter ranges: {hw}"
    );
    Ok(DesignReport { hw, cycles: num("cycles")?, power_w: num("power_w")?, edp: num("edp")? })
}

fn outcome_fields(o: &SearchOutcome) -> Vec<(&'static str, Json)> {
    vec![
        ("optimizer", Json::Str(o.optimizer.clone())),
        ("designs", Json::Arr(o.ranked.iter().map(design_to_json).collect())),
        ("trace", Json::arr_f64(&o.trace)),
        ("evals", Json::Num(o.evals as f64)),
        ("search_time_s", Json::Num(o.search_time_s)),
    ]
}

fn outcome_from_json(j: &Json) -> Result<SearchOutcome> {
    let ranked = j
        .get("designs")
        .as_arr()
        .context("outcome.designs")?
        .iter()
        .map(design_from_json)
        .collect::<Result<Vec<_>>>()?;
    let trace = j.get("trace").as_f64_vec().context("outcome.trace")?;
    Ok(SearchOutcome {
        optimizer: j.get("optimizer").as_str().unwrap_or("").to_string(),
        evals: j.get("evals").as_usize().unwrap_or(trace.len()),
        search_time_s: j.get("search_time_s").as_f64().unwrap_or(0.0),
        ranked,
        trace,
    })
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Designs(ds) => Json::obj(vec![
                ("status", Json::Str("ok".into())),
                ("designs", Json::Arr(ds.iter().map(design_to_json).collect())),
            ]),
            Response::Outcome(o) => {
                // carries "designs" too, so v1 readers keep working
                let mut fields = vec![
                    ("status", Json::Str("ok".into())),
                    ("v", Json::Num(PROTOCOL_VERSION as f64)),
                ];
                fields.extend(outcome_fields(o));
                Json::obj(fields)
            }
            Response::Batch(outs) => Json::obj(vec![
                ("status", Json::Str("ok".into())),
                ("v", Json::Num(PROTOCOL_VERSION as f64)),
                ("outcomes", Json::Arr(outs.iter().map(|o| Json::obj(outcome_fields(o))).collect())),
            ]),
            Response::MetricsText(s) => Json::obj(vec![
                ("status", Json::Str("ok".into())),
                ("metrics", Json::Str(s.clone())),
            ]),
            Response::Error { code, message } => Json::obj(vec![
                ("status", Json::Str("error".into())),
                ("v", Json::Num(PROTOCOL_VERSION as f64)),
                ("code", Json::Str(code.name().into())),
                ("message", Json::Str(message.clone())),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Response> {
        match j.get("status").as_str() {
            Some("ok") => {
                if let Some(m) = j.get("metrics").as_str() {
                    Ok(Response::MetricsText(m.to_string()))
                } else if let Some(outs) = j.get("outcomes").as_arr() {
                    Ok(Response::Batch(
                        outs.iter().map(outcome_from_json).collect::<Result<Vec<_>>>()?,
                    ))
                } else if !matches!(j.get("trace"), Json::Null) {
                    Ok(Response::Outcome(outcome_from_json(j)?))
                } else {
                    let ds = j
                        .get("designs")
                        .as_arr()
                        .context("designs")?
                        .iter()
                        .map(design_from_json)
                        .collect::<Result<Vec<_>>>()?;
                    Ok(Response::Designs(ds))
                }
            }
            Some("error") => Ok(Response::Error {
                code: j
                    .get("code")
                    .as_str()
                    .and_then(ErrorCode::from_name)
                    .unwrap_or(ErrorCode::Internal),
                message: j.get("message").as_str().unwrap_or("").to_string(),
            }),
            _ => bail!("bad response"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design_space::{HwConfig, LoopOrder};

    fn parse(s: &str) -> Result<Request, WireError> {
        Request::from_json(&Json::parse(s).unwrap())
    }

    #[test]
    fn generic_request_roundtrip() {
        let reqs = vec![
            Request::Search(SearchRequest::new(
                Objective::Runtime { g: Gemm::new(128, 768, 768), target_cycles: 1e6 },
                Budget::evals(32),
                OptimizerKind::DiffAxE,
            )),
            Request::Search(SearchRequest {
                objective: Objective::MinEdp { g: Gemm::new(1, 2, 3) },
                budget: Budget::evals(90).with_per_class(5).with_wall_clock(1.5),
                optimizer: OptimizerKind::VanillaBo,
                top_k: Some(3),
            }),
            Request::Search(SearchRequest::new(
                Objective::LlmEdp {
                    model: LlmModel::BertBase,
                    stage: Stage::Decode,
                    seq: 64,
                    platform: Platform::FpgaVu13p,
                },
                Budget::default().with_per_class(4),
                OptimizerKind::DosaGd,
            )),
            Request::Batch(vec![
                SearchRequest::new(
                    Objective::MaxPerf { g: Gemm::new(9, 9, 9) },
                    Budget::evals(7),
                    OptimizerKind::RandomSearch,
                ),
                SearchRequest::new(
                    Objective::MinEdp { g: Gemm::new(4, 5, 6) },
                    Budget::evals(8),
                    OptimizerKind::Fixed(crate::baselines::FixedArch::Nvdla),
                ),
            ]),
            Request::Metrics,
        ];
        for r in reqs {
            let j = Json::parse(&r.to_json().to_string()).unwrap();
            assert_eq!(Request::from_json(&j).unwrap(), r, "{r:?}");
        }
    }

    #[test]
    fn legacy_aliases_still_parse() {
        let r = parse(r#"{"type":"generate","m":128,"k":768,"n":2304,"target_cycles":1e6,"count":8}"#)
            .unwrap();
        assert_eq!(
            r,
            Request::Search(SearchRequest {
                objective: Objective::Runtime { g: Gemm::new(128, 768, 2304), target_cycles: 1e6 },
                budget: Budget::evals(8),
                optimizer: OptimizerKind::DiffAxE,
                top_k: Some(8), // v1 `generate` returned `count` designs
            })
        );
        let r = parse(r#"{"type":"edp_search","m":1,"k":2,"n":3,"per_class":5}"#).unwrap();
        assert_eq!(
            r,
            Request::Search(SearchRequest {
                objective: Objective::MinEdp { g: Gemm::new(1, 2, 3) },
                budget: Budget::default().with_per_class(5),
                optimizer: OptimizerKind::DiffAxE,
                top_k: Some(1), // v1 `edp_search` returned the single best
            })
        );
        let r = parse(r#"{"type":"perf_search","m":9,"k":9,"n":9,"count":7}"#).unwrap();
        assert!(matches!(
            r,
            Request::Search(SearchRequest {
                objective: Objective::MaxPerf { .. },
                optimizer: OptimizerKind::DiffAxE,
                ..
            })
        ));
        let r = parse(r#"{"type":"llm_search","model":"bert-base","stage":"decode","per_layer":4}"#)
            .unwrap();
        assert_eq!(
            r,
            Request::Search(SearchRequest {
                objective: Objective::LlmEdp {
                    model: LlmModel::BertBase,
                    stage: Stage::Decode,
                    seq: DEFAULT_SEQ,
                    platform: Platform::Asic32nm,
                },
                budget: Budget::default().with_per_class(4),
                optimizer: OptimizerKind::DiffAxE,
                top_k: Some(1),
            })
        );
    }

    #[test]
    fn unknown_fields_are_ignored() {
        let r = parse(
            r#"{"v":2,"type":"search","some_future_flag":true,"nested":{"x":1},
                "objective":{"kind":"min_edp","m":4,"k":5,"n":6,"hint":"fast"},
                "budget":{"evals":12,"gpu_hours":99},"optimizer":"random"}"#,
        )
        .unwrap();
        assert_eq!(
            r,
            Request::Search(SearchRequest::new(
                Objective::MinEdp { g: Gemm::new(4, 5, 6) },
                Budget::evals(12),
                OptimizerKind::RandomSearch,
            ))
        );
        // legacy form with extra fields parses too
        assert!(parse(r#"{"type":"metrics","extra":[1,2,3]}"#).is_ok());
    }

    #[test]
    fn version_mismatch_is_a_structured_error() {
        let err = parse(r#"{"v":3,"type":"search"}"#).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnsupportedVersion);
        // and it serializes into an error *response*, not a hangup
        let resp = Response::error(err.code, err.message);
        let j = Json::parse(&resp.to_json().to_string()).unwrap();
        match Response::from_json(&j).unwrap() {
            Response::Error { code, message } => {
                assert_eq!(code, ErrorCode::UnsupportedVersion);
                assert!(message.contains("v3"));
            }
            other => panic!("unexpected {other:?}"),
        }
        // a request at exactly the supported version is fine
        assert!(parse(r#"{"v":2,"type":"metrics"}"#).is_ok());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse(r#"{"type":"nope"}"#).is_err());
        assert!(parse(r#"{"type":"generate","m":1}"#).is_err());
        assert!(parse(r#"{"type":"search","objective":{"kind":"warp"}}"#).is_err());
        assert!(parse(r#"{"type":"batch","requests":[]}"#).is_err());
        // zero GEMM dims must not panic the connection thread
        let err =
            parse(r#"{"type":"generate","m":0,"k":1,"n":1,"target_cycles":1.0}"#).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        // unknown optimizer name
        let err = parse(
            r#"{"type":"search","objective":{"kind":"min_edp","m":1,"k":1,"n":1},
                "optimizer":"sgd"}"#,
        )
        .unwrap_err();
        assert!(err.message.contains("sgd"));
    }

    #[test]
    fn response_roundtrip() {
        let d = DesignReport {
            hw: HwConfig::new_kb(16, 32, 64.0, 128.0, 8.5, 12, LoopOrder::Nmk),
            cycles: 12345.0,
            power_w: 1.25,
            edp: 3.4e8,
        };
        let outcome = SearchOutcome {
            optimizer: "DiffAxE".into(),
            ranked: vec![d],
            trace: vec![0.25],
            evals: 1,
            search_time_s: 0.5,
        };
        for resp in [
            Response::Designs(vec![d]),
            Response::Outcome(outcome.clone()),
            Response::Batch(vec![outcome.clone(), outcome]),
            Response::MetricsText("requests=1".into()),
            Response::error(ErrorCode::Internal, "boom"),
        ] {
            let j = Json::parse(&resp.to_json().to_string()).unwrap();
            assert_eq!(Response::from_json(&j).unwrap(), resp);
        }
    }

    #[test]
    fn outcome_response_is_v1_readable() {
        // a v1 client reads "designs" from a v2 Outcome response
        let d = DesignReport {
            hw: HwConfig::new_kb(8, 8, 64.0, 64.0, 16.0, 8, LoopOrder::Mnk),
            cycles: 10.0,
            power_w: 0.5,
            edp: 5.0,
        };
        let out = SearchOutcome {
            optimizer: "Random Search".into(),
            ranked: vec![d],
            trace: vec![5.0],
            evals: 1,
            search_time_s: 0.0,
        };
        let j = Response::Outcome(out).to_json();
        let designs = j.get("designs").as_arr().unwrap();
        assert_eq!(designs.len(), 1);
        assert_eq!(design_from_json(&designs[0]).unwrap(), d);
    }

    #[test]
    fn design_validation_rejects_out_of_range() {
        let d = DesignReport {
            hw: HwConfig::new_kb(16, 32, 64.0, 128.0, 8.5, 12, LoopOrder::Nmk),
            cycles: 1.0,
            power_w: 1.0,
            edp: 1.0,
        };
        let mut j = design_to_json(&d);
        if let Json::Obj(o) = &mut j {
            o.insert("r".into(), Json::Num(100000.0));
        }
        assert!(design_from_json(&j).is_err());
    }
}
