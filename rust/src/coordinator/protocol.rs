//! Request/response types and their JSON wire encoding (newline-delimited
//! JSON over TCP — see [`super::server`]).

use crate::design_space::HwConfig;
use crate::util::json::Json;
use crate::workload::{Gemm, LlmModel, Stage};
use anyhow::{bail, Context, Result};

/// A DSE request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// §III-C: generate `n` designs hitting `target_cycles` on workload `g`.
    GenerateRuntime { g: Gemm, target_cycles: f64, n: usize },
    /// §III-D: power–performance class DSE, `n_per_class` designs per class.
    EdpSearch { g: Gemm, n_per_class: usize },
    /// §III-E: lowest-EDP-class generation for performance.
    PerfSearch { g: Gemm, n: usize },
    /// §VI: whole-LLM co-design.
    LlmSearch { model: LlmModel, stage: Stage, n_per_layer: usize },
    /// service introspection
    Metrics,
}

/// One evaluated design.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignReport {
    pub hw: HwConfig,
    pub cycles: f64,
    pub power_w: f64,
    pub edp: f64,
}

/// A DSE response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Designs(Vec<DesignReport>),
    MetricsText(String),
    Error(String),
}

impl Request {
    pub fn from_json(j: &Json) -> Result<Request> {
        let ty = j.get("type").as_str().context("request missing 'type'")?;
        let gemm = || -> Result<Gemm> {
            Ok(Gemm::new(
                j.get("m").as_usize().context("m")? as u32,
                j.get("k").as_usize().context("k")? as u32,
                j.get("n").as_usize().context("n")? as u32,
            ))
        };
        Ok(match ty {
            "generate" => Request::GenerateRuntime {
                g: gemm()?,
                target_cycles: j.get("target_cycles").as_f64().context("target_cycles")?,
                n: j.get("count").as_usize().unwrap_or(16),
            },
            "edp_search" => Request::EdpSearch {
                g: gemm()?,
                n_per_class: j.get("per_class").as_usize().unwrap_or(32),
            },
            "perf_search" => Request::PerfSearch {
                g: gemm()?,
                n: j.get("count").as_usize().unwrap_or(64),
            },
            "llm_search" => {
                let model = match j.get("model").as_str().unwrap_or("") {
                    "bert-base" => LlmModel::BertBase,
                    "opt-350m" => LlmModel::Opt350m,
                    "llama-2-7b" => LlmModel::Llama2_7b,
                    other => bail!("unknown model {other:?}"),
                };
                let stage = match j.get("stage").as_str().unwrap_or("prefill") {
                    "prefill" => Stage::Prefill,
                    "decode" => Stage::Decode,
                    other => bail!("unknown stage {other:?}"),
                };
                Request::LlmSearch {
                    model,
                    stage,
                    n_per_layer: j.get("per_layer").as_usize().unwrap_or(32),
                }
            }
            "metrics" => Request::Metrics,
            other => bail!("unknown request type {other:?}"),
        })
    }

    pub fn to_json(&self) -> Json {
        match self {
            Request::GenerateRuntime { g, target_cycles, n } => Json::obj(vec![
                ("type", Json::Str("generate".into())),
                ("m", Json::Num(g.m as f64)),
                ("k", Json::Num(g.k as f64)),
                ("n", Json::Num(g.n as f64)),
                ("target_cycles", Json::Num(*target_cycles)),
                ("count", Json::Num(*n as f64)),
            ]),
            Request::EdpSearch { g, n_per_class } => Json::obj(vec![
                ("type", Json::Str("edp_search".into())),
                ("m", Json::Num(g.m as f64)),
                ("k", Json::Num(g.k as f64)),
                ("n", Json::Num(g.n as f64)),
                ("per_class", Json::Num(*n_per_class as f64)),
            ]),
            Request::PerfSearch { g, n } => Json::obj(vec![
                ("type", Json::Str("perf_search".into())),
                ("m", Json::Num(g.m as f64)),
                ("k", Json::Num(g.k as f64)),
                ("n", Json::Num(g.n as f64)),
                ("count", Json::Num(*n as f64)),
            ]),
            Request::LlmSearch { model, stage, n_per_layer } => Json::obj(vec![
                ("type", Json::Str("llm_search".into())),
                (
                    "model",
                    Json::Str(
                        match model {
                            LlmModel::BertBase => "bert-base",
                            LlmModel::Opt350m => "opt-350m",
                            LlmModel::Llama2_7b => "llama-2-7b",
                        }
                        .into(),
                    ),
                ),
                ("stage", Json::Str(stage.name().into())),
                ("per_layer", Json::Num(*n_per_layer as f64)),
            ]),
            Request::Metrics => Json::obj(vec![("type", Json::Str("metrics".into()))]),
        }
    }
}

impl DesignReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("r", Json::Num(self.hw.r as f64)),
            ("c", Json::Num(self.hw.c as f64)),
            ("ip_kb", Json::Num(self.hw.ip_kb())),
            ("wt_kb", Json::Num(self.hw.wt_kb())),
            ("op_kb", Json::Num(self.hw.op_kb())),
            ("bw", Json::Num(self.hw.bw as f64)),
            ("loop_order", Json::Str(self.hw.loop_order.name().into())),
            ("cycles", Json::Num(self.cycles)),
            ("power_w", Json::Num(self.power_w)),
            ("edp", Json::Num(self.edp)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<DesignReport> {
        use crate::design_space::{LoopOrder, params};
        let num = |k: &str| j.get(k).as_f64().with_context(|| format!("design.{k}"));
        let hw = HwConfig {
            r: num("r")? as u32,
            c: num("c")? as u32,
            ip_b: (num("ip_kb")? * 1024.0).round() as u64,
            wt_b: (num("wt_kb")? * 1024.0).round() as u64,
            op_b: (num("op_kb")? * 1024.0).round() as u64,
            bw: num("bw")? as u32,
            loop_order: LoopOrder::from_name(j.get("loop_order").as_str().unwrap_or("mnk"))
                .context("loop_order")?,
        };
        let _ = params::DIM_MIN; // keep params in scope for doc-link stability
        Ok(DesignReport { hw, cycles: num("cycles")?, power_w: num("power_w")?, edp: num("edp")? })
    }
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Designs(ds) => Json::obj(vec![
                ("status", Json::Str("ok".into())),
                ("designs", Json::Arr(ds.iter().map(|d| d.to_json()).collect())),
            ]),
            Response::MetricsText(s) => Json::obj(vec![
                ("status", Json::Str("ok".into())),
                ("metrics", Json::Str(s.clone())),
            ]),
            Response::Error(e) => Json::obj(vec![
                ("status", Json::Str("error".into())),
                ("message", Json::Str(e.clone())),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Response> {
        match j.get("status").as_str() {
            Some("ok") => {
                if let Some(m) = j.get("metrics").as_str() {
                    Ok(Response::MetricsText(m.to_string()))
                } else {
                    let ds = j
                        .get("designs")
                        .as_arr()
                        .context("designs")?
                        .iter()
                        .map(DesignReport::from_json)
                        .collect::<Result<Vec<_>>>()?;
                    Ok(Response::Designs(ds))
                }
            }
            Some("error") => {
                Ok(Response::Error(j.get("message").as_str().unwrap_or("").to_string()))
            }
            _ => bail!("bad response"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let reqs = vec![
            Request::GenerateRuntime { g: Gemm::new(128, 768, 768), target_cycles: 1e6, n: 32 },
            Request::EdpSearch { g: Gemm::new(1, 2, 3), n_per_class: 5 },
            Request::PerfSearch { g: Gemm::new(9, 9, 9), n: 7 },
            Request::LlmSearch { model: LlmModel::BertBase, stage: Stage::Decode, n_per_layer: 4 },
            Request::Metrics,
        ];
        for r in reqs {
            let j = Json::parse(&r.to_json().to_string()).unwrap();
            assert_eq!(Request::from_json(&j).unwrap(), r, "{r:?}");
        }
    }

    #[test]
    fn response_roundtrip() {
        use crate::design_space::LoopOrder;
        let d = DesignReport {
            hw: HwConfig::new_kb(16, 32, 64.0, 128.0, 8.5, 12, LoopOrder::Nmk),
            cycles: 12345.0,
            power_w: 1.25,
            edp: 3.4e8,
        };
        let resp = Response::Designs(vec![d]);
        let j = Json::parse(&resp.to_json().to_string()).unwrap();
        assert_eq!(Response::from_json(&j).unwrap(), resp);
    }

    #[test]
    fn rejects_malformed() {
        let j = Json::parse(r#"{"type": "nope"}"#).unwrap();
        assert!(Request::from_json(&j).is_err());
        let j = Json::parse(r#"{"type": "generate", "m": 1}"#).unwrap();
        assert!(Request::from_json(&j).is_err());
    }
}
