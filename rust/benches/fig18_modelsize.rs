//! Fig 15(b) / Fig 18 / Fig 21: model sizes — DiffAxE component breakdown
//! and comparison against prior DL-based DSE models (AIRCHITECT v1/v2).
//!
//! Paper shape: DiffAxE ≈ 3.4 M parameters (at paper scale), ~32% smaller
//! than AIRCHITECT v2; AIRCHITECT v1's output layer dominates its size.

use diffaxe::models::NormStats;
use diffaxe::util::bench::banner;
use diffaxe::util::table::Table;

fn main() -> anyhow::Result<()> {
    banner("Fig 15(b) / 18", "model size comparison");
    let path = std::path::Path::new("artifacts/norm_stats.json");
    if !path.exists() {
        println!("SKIP: run `make artifacts` first");
        return Ok(());
    }
    let stats = NormStats::load(path)?;
    let mut t = Table::new(&["model", "parameters"]);
    let mut rows: Vec<(&String, &usize)> = stats.param_counts.iter().collect();
    rows.sort_by_key(|(_, &v)| std::cmp::Reverse(v));
    for (name, count) in rows {
        t.row(&[name.clone(), count.to_string()]);
    }
    println!("{}", t.render());
    let ddm = stats.param_counts.get("ddm").copied().unwrap_or(0);
    let ae = stats.param_counts.get("ae_pp").copied().unwrap_or(0);
    let v2 = stats.param_counts.get("airchitect_v2").copied().unwrap_or(0);
    println!(
        "DiffAxE total (DDM + AE/PP) = {} params at scale '{}' (paper: 3.4M at paper scale); \
         vs AIRCHITECT v2 {} — DiffAxE DDM smaller: {}",
        ddm + ae,
        stats.scale,
        v2,
        ddm < v2
    );
    Ok(())
}
