//! Fig 14 / Fig 15(a): Phase-1 (AE + PP) and Phase-2 (DDM) training loss
//! curves, replayed from artifacts/train_log.json (recorded at build time).

use diffaxe::util::bench::banner;
use diffaxe::util::json::Json;
use diffaxe::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    banner("Fig 14 / 15(a)", "training loss curves (from artifacts/train_log.json)");
    let path = std::path::Path::new("artifacts/train_log.json");
    if !path.exists() {
        println!("SKIP: run `make artifacts` first");
        return Ok(());
    }
    let log = Json::parse(&std::fs::read_to_string(path)?)?;
    let obj = log.as_obj().expect("train_log must be an object");
    let mut t = Table::new(&["curve", "epochs", "first", "last", "converged (last < 0.8*first)"]);
    for (name, values) in obj {
        if let Some(v) = values.as_f64_vec() {
            if v.is_empty() {
                continue;
            }
            let conv = v[v.len() - 1] < 0.8 * v[0];
            t.row(&[
                name.clone(),
                v.len().to_string(),
                fnum(v[0]),
                fnum(v[v.len() - 1]),
                conv.to_string(),
            ]);
        }
    }
    println!("{}", t.render());
    println!("paper-shape check: every loss decreases monotonically-ish to convergence (Figs 14/15a)");
    Ok(())
}
