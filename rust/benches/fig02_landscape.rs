//! Fig 2: (a) many-to-one mapping of configuration → runtime and (b) the
//! irregular, non-convex performance landscape (PCA of the design space
//! colored by runtime) for a DeiT-B QKV-style layer (decode stage).

use diffaxe::design_space::{encode_norm, params::TrainingSpace};
use diffaxe::sim::simulate;
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::linalg::Mat;
use diffaxe::util::pca::Pca;
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::Gemm;
use std::collections::HashMap;

fn main() {
    banner("Fig 2", "many-to-one + non-convex runtime landscape (DeiT-B QKV, decode)");
    // DeiT-B QKV decode: M=1 token, hidden 768, QKV output 2304
    let g = Gemm::new(1, 768, 2304);
    let scale = BenchScale::from_env();
    let stride = scale.pick(31, 7, 1); // 1 => full 7.76e4 points as in the paper

    let mut rows = Vec::new();
    let mut runtimes = Vec::new();
    for (i, hw) in TrainingSpace::enumerate().enumerate() {
        if i % stride != 0 {
            continue;
        }
        let r = simulate(&hw, &g);
        runtimes.push(r.cycles as f64);
        rows.push(encode_norm(&hw).iter().map(|&x| x as f64).collect::<Vec<_>>());
    }
    println!("evaluated {} design points", runtimes.len());

    // (a) many-to-one: collision histogram of exact runtimes
    let mut by_rt: HashMap<u64, u32> = HashMap::new();
    for &rt in &runtimes {
        *by_rt.entry(rt as u64).or_default() += 1;
    }
    let mut collisions: Vec<u32> = by_rt.values().copied().collect();
    collisions.sort_unstable_by(|a, b| b.cmp(a));
    let many_to_one = collisions.iter().filter(|&&c| c > 1).count();
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["distinct runtimes".into(), by_rt.len().to_string()]);
    t.row(&["configs sharing a runtime".into(),
            format!("{} groups (max group {})", many_to_one, collisions[0])]);
    t.row(&["design points / distinct runtime".into(),
            fnum(runtimes.len() as f64 / by_rt.len() as f64)]);
    println!("{}", t.render());

    // (b) PCA of configurations, runtime variance within neighborhoods:
    // non-convexity proxy = how wildly runtime varies among nearest
    // neighbors in PCA space
    let x = Mat::from_rows(&rows);
    let pca = Pca::fit(&x, 2, 1);
    let proj = pca.transform(&x);
    // bucket the 2-D projection into a coarse grid; report within-cell
    // runtime range (log10) — large ranges = discontinuous landscape
    let mut cells: HashMap<(i32, i32), (f64, f64)> = HashMap::new();
    for i in 0..proj.rows {
        let key = ((proj[(i, 0)] * 8.0) as i32, (proj[(i, 1)] * 8.0) as i32);
        let e = cells.entry(key).or_insert((f64::INFINITY, 0.0f64));
        e.0 = e.0.min(runtimes[i]);
        e.1 = e.1.max(runtimes[i]);
    }
    let spans: Vec<f64> =
        cells.values().filter(|(lo, hi)| *hi > *lo).map(|(lo, hi)| (hi / lo).log10()).collect();
    let s = diffaxe::util::stats::summarize(&spans);
    println!(
        "PCA(2) explained variance: {:.2?}; within-cell runtime span: median {:.2} decades, \
         max {:.2} decades across {} cells",
        pca.explained_variance,
        diffaxe::util::stats::percentile(&spans, 50.0),
        s.max,
        cells.len()
    );
    println!(
        "paper-shape check: many-to-one (avg {:.1} configs/runtime > 1) and >1-decade \
         within-neighborhood spans => non-invertible, non-convex (Fig 2)",
        runtimes.len() as f64 / by_rt.len() as f64
    );
}
