//! Micro-benchmarks of the L3 hot path pieces: simulator throughput,
//! energy evaluation, encoding/rounding, the SoA batch simulator vs the
//! scalar loop (`sim_scalar/sim_batch_candidates_per_s`,
//! `sim_batch_speedup`), the batched-vs-scalar evaluation hot path, the
//! memoized/pooled evaluation core (pooled-vs-spawn, cache hit rate,
//! LlmEdp candidate throughput vs the pre-memoization path), and the
//! trace oracle for comparison. These drive the §Perf iteration in
//! EXPERIMENTS.md; the eval-core sections also emit
//! `BENCH_eval_core.json` so the perf trajectory is machine-readable
//! (`tools/bench-history` accumulates the per-commit stream and gates CI
//! on regressions).

use diffaxe::design_space::{decode_rounded, encode_norm, HwConfig, TargetSpace};
use diffaxe::dse::eval::{par_map, EvalCache};
use diffaxe::dse::llm::{eval_model_reference, Platform};
use diffaxe::dse::{coarsen, Objective};
use diffaxe::energy::{asic, fpga};
use diffaxe::sim::{simulate, simulate_batch, trace};
use diffaxe::util::bench::{banner, time_mean, BenchScale};
use diffaxe::util::json::Json;
use diffaxe::util::rng::Pcg32;
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::{Gemm, LlmModel, Stage};
use std::collections::BTreeMap;
use std::hint::black_box;

/// The pre-PR batched evaluation path, retained for comparison: one scoped
/// thread spawn per call (what the persistent `WorkerPool` replaced).
fn spawn_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if threads <= 1 || items.len() < 64 {
        return items.iter().map(|t| f(t)).collect();
    }
    let chunk = items.len().div_ceil(threads);
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = items
            .chunks(chunk)
            .map(|c| s.spawn(move || c.iter().map(f).collect::<Vec<R>>()))
            .collect();
        let mut out = Vec::with_capacity(items.len());
        for h in handles {
            out.extend(h.join().expect("evaluation worker panicked"));
        }
        out
    })
}

fn main() {
    banner("micro:sim", "simulator + evaluation-pipeline throughput");
    let scale = BenchScale::from_env();
    let n = scale.pick(20_000, 200_000, 1_000_000);
    let mut rng = Pcg32::seeded(1);
    let configs: Vec<_> = (0..4096).map(|_| TargetSpace::sample(&mut rng)).collect();
    let gemms = [
        Gemm::new(128, 768, 2304),
        Gemm::new(1, 4096, 12288),
        Gemm::new(512, 3072, 16384),
    ];

    let mut t = Table::new(&["operation", "ns/op", "ops/s"]);
    let mut bench = |name: &str, mut f: Box<dyn FnMut(usize)>| {
        let per = time_mean(1, || {
            for i in 0..n {
                f(i);
            }
        }) / n as f64;
        t.row(&[name.to_string(), fnum(per * 1e9), fnum(1.0 / per)]);
    };

    let cfg2 = configs.clone();
    bench(
        "analytical simulate",
        Box::new(move |i| {
            black_box(simulate(&cfg2[i % 4096], &gemms[i % 3]));
        }),
    );
    let cfg3 = configs.clone();
    bench(
        "simulate + asic energy",
        Box::new(move |i| {
            let hw = &cfg3[i % 4096];
            let s = simulate(hw, &gemms[i % 3]);
            black_box(asic::evaluate(hw, &s));
        }),
    );
    let cfg4 = configs.clone();
    bench(
        "simulate + fpga energy",
        Box::new(move |i| {
            let hw = &cfg4[i % 4096];
            let s = simulate(hw, &gemms[i % 3]);
            black_box(fpga::evaluate(hw, &s));
        }),
    );
    let cfg5 = configs.clone();
    bench(
        "encode + decode_rounded",
        Box::new(move |i| {
            let v = encode_norm(&cfg5[i % 4096]);
            black_box(decode_rounded(&v));
        }),
    );
    println!("{}", t.render());

    // batched vs scalar evaluation: the shared vectorized objective every
    // optimizer runs on (dse::evaluate_batch memoizes through the shared
    // EvalCache and partitions the batch over the persistent pool; results
    // are bit-identical to the scalar loop)
    let g_batch = gemms[0];
    let batch = &configs[..1024];
    let reps = scale.pick(3, 10, 30);
    let t_scalar = time_mean(reps, || {
        for hw in batch {
            black_box(diffaxe::dse::evaluate(hw, &g_batch));
        }
    });
    let t_batch = time_mean(reps, || {
        black_box(diffaxe::dse::evaluate_batch(batch, &g_batch));
    });
    println!(
        "evaluate 1024 configs: scalar {:.2} ms, evaluate_batch (pooled + memoized) {:.2} ms \
         => {:.1}x speedup",
        t_scalar * 1e3,
        t_batch * 1e3,
        t_scalar / t_batch
    );

    let mut json = BTreeMap::new();

    // --- SoA batch simulator vs the scalar loop (sim/batch.rs) -----------
    // Raw single-thread simulator throughput, no cache and no pool: the
    // structure-of-arrays layout + per-LoopOrder branch hoisting is the
    // whole difference (bit-identical results by the scalar-oracle
    // guarantee, enforced in tests/sim_batch_props.rs).
    let soa_g = gemms[0];
    let soa_reps = scale.pick(5, 20, 50);
    let t_sim_scalar = time_mean(soa_reps, || {
        for hw in &configs {
            black_box(simulate(hw, &soa_g));
        }
    });
    let t_sim_batch = time_mean(soa_reps, || {
        black_box(simulate_batch(&configs, &soa_g));
    });
    let sim_n = configs.len() as f64;
    let (sim_scalar_cps, sim_batch_cps) = (sim_n / t_sim_scalar, sim_n / t_sim_batch);
    println!(
        "SoA batch simulate ({} cfgs, 1 thread): scalar {:.0}/s, batch {:.0}/s => {:.2}x",
        configs.len(),
        sim_scalar_cps,
        sim_batch_cps,
        sim_batch_cps / sim_scalar_cps
    );
    json.insert("sim_scalar_candidates_per_s".into(), Json::Num(sim_scalar_cps));
    json.insert("sim_batch_candidates_per_s".into(), Json::Num(sim_batch_cps));
    json.insert("sim_batch_speedup".into(), Json::Num(sim_batch_cps / sim_scalar_cps));

    // --- pooled vs spawn: many small batches, the coordinator's shape ----
    // The continuous batcher serves a stream of modest batches; the win of
    // the persistent pool is amortizing thread spawn across them. Both
    // sides run the identical uncached closure, isolating spawn cost from
    // the memoization win measured below.
    let small_batch = &configs[..96];
    let n_batches = scale.pick(20, 100, 400);
    let t_spawn = time_mean(reps, || {
        for _ in 0..n_batches {
            black_box(spawn_map(small_batch, |hw| diffaxe::dse::evaluate(hw, &g_batch)));
        }
    });
    let t_pool = time_mean(reps, || {
        for _ in 0..n_batches {
            black_box(par_map(small_batch, move |hw| diffaxe::dse::evaluate(hw, &g_batch)));
        }
    });
    let pool_speedup = t_spawn / t_pool;
    println!(
        "pooled vs spawn ({n_batches} batches x 96 cfgs): spawn {:.2} ms, pool {:.2} ms \
         => {:.2}x speedup",
        t_spawn * 1e3,
        t_pool * 1e3,
        pool_speedup
    );
    json.insert("pooled_vs_spawn_speedup".into(), Json::Num(pool_speedup));

    // --- cache hit rate: recurring rounded design points (Fig 2a) --------
    // Searches revisit grid points constantly (FD probes, decoder rounding
    // many-to-one); model that as a small distinct pool visited repeatedly.
    let distinct: Vec<HwConfig> = {
        let mut rng = Pcg32::seeded(33);
        (0..512).map(|_| coarsen(&TargetSpace::sample(&mut rng))).collect()
    };
    let visits = scale.pick(4_096, 16_384, 65_536);
    let cache = EvalCache::new(EvalCache::DEFAULT_SHARDS, EvalCache::DEFAULT_CAP_PER_SHARD);
    let t_uncached = time_mean(reps, || {
        for i in 0..visits {
            black_box(diffaxe::dse::evaluate(&distinct[i % 512], &g_batch));
        }
    });
    let t_cached = time_mean(reps, || {
        for i in 0..visits {
            black_box(cache.evaluate(&distinct[i % 512], &g_batch));
        }
    });
    let cstats = cache.stats();
    let cache_speedup = t_uncached / t_cached;
    println!(
        "eval cache ({visits} visits over 512 distinct): uncached {:.0} ns/op, cached {:.0} \
         ns/op => {:.2}x; {cstats}",
        t_uncached / visits as f64 * 1e9,
        t_cached / visits as f64 * 1e9,
        cache_speedup
    );
    json.insert("cache_hit_rate".into(), Json::Num(cstats.hit_rate()));
    json.insert("cache_speedup".into(), Json::Num(cache_speedup));

    // --- LlmEdp candidate throughput: the §VI co-design hot loop ---------
    // Pre-PR path: per-call layer_gemms alloc, one full simulate + energy
    // evaluation per (layer, order) probe, a simulate_seq re-simulation,
    // and a thread spawn per batch. New core: memoized workload, one
    // cached simulation per (shape, order), coefficient dot products, the
    // persistent pool, and the shared eval cache.
    let obj = Objective::LlmEdp {
        model: LlmModel::BertBase,
        stage: Stage::Prefill,
        seq: 128,
        platform: Platform::Asic32nm,
    };
    let stream: Vec<HwConfig> = {
        let mut rng = Pcg32::seeded(34);
        let pool: Vec<HwConfig> =
            (0..64).map(|_| coarsen(&TargetSpace::sample(&mut rng))).collect();
        (0..scale.pick(128, 256, 1024)).map(|i| pool[i % 64]).collect()
    };
    let llm_reps = scale.pick(2, 5, 10);
    let t_ref = time_mean(llm_reps, || {
        black_box(spawn_map(&stream, |hw| {
            eval_model_reference(hw, LlmModel::BertBase, Stage::Prefill, 128, Platform::Asic32nm)
                .energy
                .edp
        }));
    });
    // cold pass: all-distinct candidates + cleared cache, so intra-stream
    // duplicates cannot hide behind memoization — this is the pure
    // algorithmic fast-path win over the reference
    let fresh: Vec<HwConfig> = {
        let mut rng = Pcg32::seeded(35);
        (0..stream.len()).map(|_| TargetSpace::sample(&mut rng)).collect()
    };
    let t_cold = time_mean(llm_reps, || {
        EvalCache::global().clear();
        black_box(obj.evaluate_all(&fresh));
    });
    let t_warm = time_mean(llm_reps, || {
        black_box(obj.evaluate_all(&stream));
    });
    let n_cand = stream.len() as f64;
    let (ref_cps, cold_cps, warm_cps) = (n_cand / t_ref, n_cand / t_cold, n_cand / t_warm);
    println!(
        "LlmEdp candidates/sec (BERT prefill, {} candidates):\n\
         \x20 pre-PR (spawn + reference eval):          {:.0}/s\n\
         \x20 eval core, cold + all-distinct:           {:.0}/s ({:.2}x)\n\
         \x20 eval core, steady state (64 distinct):    {:.0}/s ({:.2}x)",
        stream.len(),
        ref_cps,
        cold_cps,
        cold_cps / ref_cps,
        warm_cps,
        warm_cps / ref_cps
    );
    json.insert("llm_ref_candidates_per_s".into(), Json::Num(ref_cps));
    json.insert("llm_cold_candidates_per_s".into(), Json::Num(cold_cps));
    json.insert("llm_warm_candidates_per_s".into(), Json::Num(warm_cps));
    json.insert("llm_speedup_cold".into(), Json::Num(cold_cps / ref_cps));
    json.insert("llm_speedup_warm".into(), Json::Num(warm_cps / ref_cps));
    json.insert("batch_speedup".into(), Json::Num(t_scalar / t_batch));

    let out = Json::Obj(json).to_string();
    std::fs::write("BENCH_eval_core.json", &out).expect("write BENCH_eval_core.json");
    println!("wrote BENCH_eval_core.json: {out}");

    // trace oracle cost for context (not on the hot path)
    let small = Gemm::new(64, 256, 64);
    let per = time_mean(scale.pick(200, 2_000, 20_000), || {
        black_box(trace::simulate(&configs[0], &small));
    });
    println!("trace-oracle simulate (64x256x64): {:.1} us/op (test-only path)", per * 1e6);

    // job-registry bookkeeping cost + the coordinator's job/queue gauges
    // (the engine-free registry is the serving path's per-search overhead:
    // submit -> start -> publish -> finalize, with bounded GC)
    bench_job_registry(&scale);
}

fn bench_job_registry(scale: &BenchScale) {
    use diffaxe::coordinator::{JobRegistry, JobState, Metrics, Response, SearchRequest};
    use diffaxe::dse::{Budget, OptimizerKind, SearchEvent, SearchOutcome, StopReason};
    use std::sync::Arc;

    let metrics = Arc::new(Metrics::new());
    let reg = JobRegistry::new(metrics.clone());
    let g = Gemm::new(128, 768, 2304);
    let obj = Objective::MinEdp { g };
    let n_jobs = scale.pick(2_000, 20_000, 200_000);
    let timer = diffaxe::util::stats::Timer::start();
    for i in 0..n_jobs {
        let req = SearchRequest::new(obj, Budget::evals(8), OptimizerKind::RandomSearch);
        let entry = reg.submit(req);
        reg.start(&entry);
        reg.publish(&entry, SearchEvent { evals: 8, best_score: 1.0, elapsed_s: 0.0 });
        let outcome = SearchOutcome::from_reports("bench", &obj, Vec::new(), 0.0);
        let (state, stopped) = if i % 8 == 0 {
            (JobState::Cancelled, StopReason::Cancelled)
        } else {
            (JobState::Done, StopReason::Completed)
        };
        reg.finalize(&entry, state, Response::Outcome(outcome.with_stopped(stopped)));
    }
    let dt = timer.elapsed_s();
    println!(
        "job registry lifecycle (submit+start+publish+finalize): {:.2} us/job \
         ({} jobs, {} retained after GC)",
        dt / n_jobs as f64 * 1e6,
        n_jobs,
        reg.list().len()
    );
    // the same gauges the coordinator exports in its metrics snapshot
    println!("job gauges: {}", metrics.snapshot());
}
