//! Micro-benchmarks of the L3 hot path pieces: simulator throughput,
//! energy evaluation, encoding/rounding, the batched-vs-scalar evaluation
//! hot path, and the trace oracle for comparison. These drive the §Perf
//! iteration in EXPERIMENTS.md.

use diffaxe::design_space::{decode_rounded, encode_norm, TargetSpace};
use diffaxe::energy::{asic, fpga};
use diffaxe::sim::{simulate, trace};
use diffaxe::util::bench::{banner, time_mean, BenchScale};
use diffaxe::util::rng::Pcg32;
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::Gemm;
use std::hint::black_box;

fn main() {
    banner("micro:sim", "simulator + evaluation-pipeline throughput");
    let scale = BenchScale::from_env();
    let n = scale.pick(20_000, 200_000, 1_000_000);
    let mut rng = Pcg32::seeded(1);
    let configs: Vec<_> = (0..4096).map(|_| TargetSpace::sample(&mut rng)).collect();
    let gemms = [
        Gemm::new(128, 768, 2304),
        Gemm::new(1, 4096, 12288),
        Gemm::new(512, 3072, 16384),
    ];

    let mut t = Table::new(&["operation", "ns/op", "ops/s"]);
    let mut bench = |name: &str, mut f: Box<dyn FnMut(usize)>| {
        let per = time_mean(1, || {
            for i in 0..n {
                f(i);
            }
        }) / n as f64;
        t.row(&[name.to_string(), fnum(per * 1e9), fnum(1.0 / per)]);
    };

    let cfg2 = configs.clone();
    bench(
        "analytical simulate",
        Box::new(move |i| {
            black_box(simulate(&cfg2[i % 4096], &gemms[i % 3]));
        }),
    );
    let cfg3 = configs.clone();
    bench(
        "simulate + asic energy",
        Box::new(move |i| {
            let hw = &cfg3[i % 4096];
            let s = simulate(hw, &gemms[i % 3]);
            black_box(asic::evaluate(hw, &s));
        }),
    );
    let cfg4 = configs.clone();
    bench(
        "simulate + fpga energy",
        Box::new(move |i| {
            let hw = &cfg4[i % 4096];
            let s = simulate(hw, &gemms[i % 3]);
            black_box(fpga::evaluate(hw, &s));
        }),
    );
    let cfg5 = configs.clone();
    bench(
        "encode + decode_rounded",
        Box::new(move |i| {
            let v = encode_norm(&cfg5[i % 4096]);
            black_box(decode_rounded(&v));
        }),
    );
    println!("{}", t.render());

    // batched vs scalar evaluation: the shared vectorized objective every
    // optimizer runs on (dse::evaluate_batch partitions the batch over
    // threads; results are bit-identical to the scalar loop)
    let g_batch = gemms[0];
    let batch = &configs[..1024];
    let reps = scale.pick(3, 10, 30);
    let t_scalar = time_mean(reps, || {
        for hw in batch {
            black_box(diffaxe::dse::evaluate(hw, &g_batch));
        }
    });
    let t_batch = time_mean(reps, || {
        black_box(diffaxe::dse::evaluate_batch(batch, &g_batch));
    });
    println!(
        "evaluate 1024 configs: scalar {:.2} ms, evaluate_batch {:.2} ms => {:.1}x speedup",
        t_scalar * 1e3,
        t_batch * 1e3,
        t_scalar / t_batch
    );

    // trace oracle cost for context (not on the hot path)
    let small = Gemm::new(64, 256, 64);
    let per = time_mean(scale.pick(200, 2_000, 20_000), || {
        black_box(trace::simulate(&configs[0], &small));
    });
    println!("trace-oracle simulate (64x256x64): {:.1} us/op (test-only path)", per * 1e6);
}
