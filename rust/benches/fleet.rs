//! Fleet scaling bench: candidates/sec for the same concurrent request
//! mix served by a 1-worker vs a 4-worker engine fleet (PR 9's headline:
//! a worker crash degrades capacity, and capacity is horizontal). Also
//! reports the shared eval-cache hit rate observed through the service
//! `Snapshot` — the cache is process-wide, so hits accumulate across
//! tenants and phases.
//!
//! **Hermetic**: always runs on the mock engine (even when `artifacts/`
//! is present) so the history points are comparable across hosts. All
//! keys avoid the bench-history gate patterns (`*_candidates_per_s`,
//! `structured_cps_*`) by construction: fleet scaling moves with runner
//! core counts, so it rides along ungated.

use diffaxe::coordinator::{Request, Response, SearchRequest, Service, ServiceConfig};
use diffaxe::dse::{Budget, Objective, OptimizerKind};
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::json::Json;
use diffaxe::util::stats::Timer;
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::Gemm;
use std::collections::BTreeMap;

/// Serve `n_req` concurrent Runtime searches on a fresh mock-engine fleet
/// of `workers`; returns (designs, wall seconds, cache hit rate).
fn run_mix(
    workers: usize,
    n_req: usize,
    per_req: usize,
    gemms: &[Gemm],
) -> anyhow::Result<(usize, f64, f64)> {
    let mut cfg = ServiceConfig::mock();
    cfg.workers = workers;
    cfg.max_queued = 2 * n_req + 16;
    let svc = Service::start(cfg)?;
    let timer = Timer::start();
    let rxs: Vec<_> = (0..n_req)
        .map(|i| {
            let g = gemms[i % gemms.len()];
            svc.handle().submit(Request::Search(SearchRequest::new(
                Objective::Runtime { g, target_cycles: 4e5 + 1e5 * (i % 5) as f64 },
                Budget::evals(per_req),
                OptimizerKind::DiffAxE,
            )))
        })
        .collect();
    let mut designs = 0usize;
    for rx in rxs {
        match rx.recv()? {
            Response::Outcome(o) => designs += o.evals,
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }
    let dt = timer.elapsed_s();
    let snap = svc.handle().metrics().snapshot();
    Ok((designs, dt, snap.cache_hit_rate()))
}

fn main() -> anyhow::Result<()> {
    banner("micro:fleet", "multi-worker engine fleet scaling (mock backend)");
    let scale = BenchScale::from_env();
    let n_req = scale.pick(16, 48, 96);
    let per_req = 32;
    // distinct GEMM sets per phase so the process-wide shared cache can't
    // warm one phase from the other and skew the scaling ratio
    let gemms_w1 =
        [Gemm::new(128, 768, 2304), Gemm::new(128, 768, 768), Gemm::new(64, 256, 512)];
    let gemms_w4 =
        [Gemm::new(96, 512, 2048), Gemm::new(96, 512, 512), Gemm::new(48, 192, 384)];

    let mut t = Table::new(&["workers", "requests", "designs", "wall (s)", "cand/s", "hit rate"]);
    let mut json: BTreeMap<String, Json> = BTreeMap::new();
    let (d1, t1, _) = run_mix(1, n_req, per_req, &gemms_w1)?;
    let cps1 = d1 as f64 / t1.max(1e-9);
    t.row(&["1".into(), n_req.to_string(), d1.to_string(), fnum(t1), fnum(cps1), "-".into()]);
    let (d4, t4, hit_rate) = run_mix(4, n_req, per_req, &gemms_w4)?;
    let cps4 = d4 as f64 / t4.max(1e-9);
    t.row(&[
        "4".into(),
        n_req.to_string(),
        d4.to_string(),
        fnum(t4),
        fnum(cps4),
        fnum(hit_rate),
    ]);
    println!("{}", t.render());

    let scaling = cps4 / cps1.max(1e-9);
    println!(
        "fleet scaling: {scaling:.2}x candidates/sec at workers=4 vs 1 (target: >=2x on >=4 cores)"
    );
    json.insert("fleet_w1_cps".into(), Json::Num(cps1));
    json.insert("fleet_w4_cps".into(), Json::Num(cps4));
    json.insert("fleet_scaling".into(), Json::Num(scaling));
    json.insert("fleet_cache_hit_rate".into(), Json::Num(hit_rate));
    let out = Json::Obj(json).to_string();
    std::fs::write("BENCH_fleet.json", &out).expect("write BENCH_fleet.json");
    println!("wrote BENCH_fleet.json: {out}");
    Ok(())
}
