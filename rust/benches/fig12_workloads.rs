//! Fig 12: distribution of the 600-workload evaluation suite over the
//! (M, K, N) ranges of §IV-A.

use diffaxe::util::bench::banner;
use diffaxe::util::stats::percentile;
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::WorkloadSuite;

fn main() {
    banner("Fig 12", "workload suite distribution (600 GEMMs)");
    let suite = WorkloadSuite::generate(WorkloadSuite::PAPER_SIZE, 1);
    let ms: Vec<f64> = suite.workloads.iter().map(|g| g.m as f64).collect();
    let ks: Vec<f64> = suite.workloads.iter().map(|g| g.k as f64).collect();
    let ns: Vec<f64> = suite.workloads.iter().map(|g| g.n as f64).collect();
    let mut t = Table::new(&["dim", "min", "p25", "p50", "p75", "max"]);
    for (name, xs) in [("M", &ms), ("K", &ks), ("N", &ns)] {
        t.row(&[
            name.to_string(),
            fnum(percentile(xs, 0.0)),
            fnum(percentile(xs, 25.0)),
            fnum(percentile(xs, 50.0)),
            fnum(percentile(xs, 75.0)),
            fnum(percentile(xs, 100.0)),
        ]);
    }
    println!("{}", t.render());
    println!(
        "{} distinct workloads; ranges match §IV-A (M 1-1024, K 1-4096, N 1-30000); \
         includes BERT/OPT/LLaMA layer shapes at seq 32/128/512",
        suite.len()
    );
}
