//! Fig 7 / Fig 11: the Phase-1 latent space is organized by performance —
//! PCA of encoded configurations shows runtime varying smoothly (Fig 7) and
//! power–performance classes clustering (Fig 11), unlike the raw space
//! (Fig 2(b)).

use diffaxe::design_space::{encode_norm, params::TrainingSpace};
use diffaxe::models::DiffAxE;
use diffaxe::sim::simulate;
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::linalg::Mat;
use diffaxe::util::pca::Pca;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    banner("Fig 7/11", "performance-organized latent space (PCA)");
    let dir = Path::new("artifacts");
    if !DiffAxE::artifacts_present(dir) {
        println!("SKIP: run `make artifacts` first");
        return Ok(());
    }
    let engine = DiffAxE::load(dir)?;
    // GPT-2 MLP2 decode-style layer (paper's Fig 7 example): M=1, K=3072, N=768
    let g = diffaxe::workload::Gemm::new(1, 3072, 768);
    let st = engine.stats.stats_for(&g);
    let scale = BenchScale::from_env();
    let stride = scale.pick(97, 31, 7);

    let mut hw_rows = Vec::new();
    let mut rts = Vec::new();
    for (i, hw) in TrainingSpace::enumerate().enumerate() {
        if i % stride != 0 {
            continue;
        }
        hw_rows.push(encode_norm(&hw).to_vec());
        rts.push(st.norm_runtime(simulate(&hw, &g).cycles as f64) as f64);
    }
    let latents = engine.encode(&hw_rows)?;
    let lat_rows: Vec<Vec<f64>> =
        latents.iter().map(|l| l.iter().map(|&x| x as f64).collect()).collect();

    // correlation between PCA coordinates and runtime: high in latent space
    // (smooth gradient, Fig 7), low in the raw space (Fig 2(b))
    let raw_corr = pca_runtime_corr(
        &hw_rows.iter().map(|r| r.iter().map(|&x| x as f64).collect()).collect::<Vec<_>>(),
        &rts,
    );
    let lat_corr = pca_runtime_corr(&lat_rows, &rts);
    println!(
        "|corr(PC1..2, runtime)|: raw space {:.3}, latent space {:.3} over {} points",
        raw_corr,
        lat_corr,
        rts.len()
    );
    println!(
        "paper-shape check: latent space organized by performance => latent corr >> raw corr: {}",
        lat_corr > raw_corr
    );
    Ok(())
}

/// max |pearson| between the top-2 principal coordinates and runtime.
fn pca_runtime_corr(rows: &[Vec<f64>], rts: &[f64]) -> f64 {
    let x = Mat::from_rows(rows);
    let pca = Pca::fit(&x, 2, 3);
    let proj = pca.transform(&x);
    let mut best: f64 = 0.0;
    for c in 0..2 {
        let coords: Vec<f64> = (0..proj.rows).map(|i| proj[(i, c)]).collect();
        best = best.max(pearson(&coords, rts).abs());
    }
    best
}

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let cov: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum();
    let va: f64 = a.iter().map(|x| (x - ma) * (x - ma)).sum();
    let vb: f64 = b.iter().map(|y| (y - mb) * (y - mb)).sum();
    cov / (va.sqrt() * vb.sqrt()).max(1e-12)
}
