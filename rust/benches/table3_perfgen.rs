//! Table III / Fig 16: runtime-conditioned hardware generation —
//! `error_gen` and search time for DiffAxE vs vanilla GD (DOSA), vanilla
//! BO, latent GD (Polaris), latent BO (VAESA) and GANDSE, every method
//! driven through the one `Optimizer` trait.
//!
//! Paper shape to reproduce: DiffAxE achieves the lowest error_gen at
//! millisecond-scale per-configuration time; latent methods beat vanilla;
//! GANDSE is fast but inaccurate (surrogate error).

use diffaxe::baselines::{BoOptions, GdOptions};
use diffaxe::dse::api::{Budget, GanDse, LatentBo, Polaris, VanillaBo, VanillaGd};
use diffaxe::dse::perfgen::{self, ErrorStat};
use diffaxe::models::DiffAxE;
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::Gemm;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    banner("Table III / Fig 16", "runtime-specific hardware generation");
    let dir = Path::new("artifacts");
    if !DiffAxE::artifacts_present(dir) {
        println!("SKIP: run `make artifacts` first");
        return Ok(());
    }
    let mut engine = DiffAxE::load(dir)?;
    let scale = BenchScale::from_env();
    let n_workloads = scale.pick(2, 8, engine.stats.workloads.len());
    let n_targets = scale.pick(2, 5, 20); // paper: 20
    let n_designs = scale.pick(16, 64, 1000); // paper: 1000
    let workloads: Vec<Gemm> =
        engine.stats.workloads.iter().take(n_workloads).map(|w| w.gemm).collect();
    let queries = perfgen::make_queries(&engine, &workloads, n_targets);
    println!(
        "{} workloads x {} targets = {} queries; {} designs/query (diffusion)",
        n_workloads,
        n_targets,
        queries.len(),
        n_designs
    );

    let bo_opts = BoOptions {
        n_init: scale.pick(6, 10, 16),
        budget: scale.pick(15, 40, 120),
        pool: scale.pick(64, 200, 512),
        ..Default::default()
    };
    let gd_opts = GdOptions {
        steps: scale.pick(20, 50, 100),
        restarts: scale.pick(2, 3, 6),
        ..Default::default()
    };
    // budgets: the generative methods amortize a design batch; the
    // optimization baselines run their own schedules under a generous cap
    let gen_budget = Budget::evals(n_designs);
    let bo_budget = Budget::evals(bo_opts.budget);
    let gd_budget = Budget::evals(1_000_000);

    let mut results = Vec::new();
    results.push(perfgen::evaluate_method(
        &mut VanillaGd { engine: Some(&engine), opts: gd_opts.clone() },
        &queries,
        &gd_budget,
        ErrorStat::BestFound,
        1,
    )?);
    results.push(perfgen::evaluate_method(
        &mut VanillaBo { opts: bo_opts.clone() },
        &queries,
        &bo_budget,
        ErrorStat::BestFound,
        2,
    )?);
    results.push(perfgen::evaluate_method(
        &mut Polaris { engine: &engine, opts: gd_opts.clone() },
        &queries,
        &gd_budget,
        ErrorStat::BestFound,
        3,
    )?);
    results.push(perfgen::evaluate_method(
        &mut LatentBo { engine: &engine, opts: bo_opts.clone() },
        &queries,
        &bo_budget,
        ErrorStat::BestFound,
        4,
    )?);
    results.push(perfgen::evaluate_method(
        &mut GanDse { engine: &engine },
        &queries,
        &gen_budget,
        ErrorStat::MeanOfGenerated,
        5,
    )?);
    results.push(perfgen::evaluate_method(
        &mut engine,
        &queries,
        &gen_budget,
        ErrorStat::MeanOfGenerated,
        6,
    )?);

    let mut t = Table::new(&["Method", "Time/query (s)", "Time/design (ms)", "error_gen (%)"]);
    for r in &results {
        // optimization baselines return ONE design per query; the generative
        // methods amortize a batch of n_designs (the paper reports 1.83 ms
        // per configuration for DiffAxE on this basis)
        let per_design = if r.name == "DiffAxE" || r.name == "GANDSE" {
            r.search_time_s / n_designs as f64
        } else {
            r.search_time_s
        };
        t.row(&[
            r.name.clone(),
            fnum(r.search_time_s),
            fnum(per_design * 1e3),
            fnum(r.error_gen * 100.0),
        ]);
    }
    println!("{}", t.render());

    let diff = results.last().unwrap();
    let latent_bo = &results[3];
    println!(
        "paper-shape checks: DiffAxE err {:.1}% vs latent-BO {:.1}% (paper: 5.45 vs 6.31 at \
         46.7M-sample training scale); per-design speedup over latent-BO: {:.0}x \
         (paper: ~17000x). NOTE: DiffAxE error averages over ALL generated designs \
         (paper protocol); the baselines report their single best-found design.",
        diff.error_gen * 100.0,
        latent_bo.error_gen * 100.0,
        latent_bo.search_time_s / (diff.search_time_s / n_designs as f64)
    );
    Ok(())
}
