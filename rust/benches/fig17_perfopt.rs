//! Fig 17 / Fig 19 / Table V: DSE for performance — normalized runtime and
//! search time vs AIRCHITECT v1/v2, VAESA (latent BO), and the best
//! configuration in the training data.
//!
//! Paper shape: DiffAxE fastest designs (lowest normalized runtime), large
//! search-time advantage over VAESA, and generated designs beating the best
//! training-set configuration (Fig 19) with bigger arrays + weight buffers
//! (Table V).

use diffaxe::baselines::BoOptions;
use diffaxe::dse::{edp, perfopt, runtime_of};
use diffaxe::models::DiffAxE;
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::stats::{geomean, Timer};
use diffaxe::util::table::{fnum, Table};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    banner("Fig 17/19, Table V", "DSE for performance optimization");
    let dir = Path::new("artifacts");
    if !DiffAxE::artifacts_present(dir) {
        println!("SKIP: run `make artifacts` first");
        return Ok(());
    }
    let engine = DiffAxE::load(dir)?;
    let scale = BenchScale::from_env();
    let n_workloads = scale.pick(2, 6, engine.stats.workloads.len());
    let n_designs = scale.pick(32, 128, 1000);
    let bo_opts = BoOptions {
        n_init: scale.pick(6, 10, 16),
        budget: scale.pick(15, 40, 150),
        pool: scale.pick(64, 200, 512),
        ..Default::default()
    };

    let mut norm_rt = vec![vec![]; 4]; // air1, air2, vaesa, train-best (normalized to DiffAxE)
    let mut times = [0.0f64; 5];
    let mut beat_training = 0usize;
    let mut example: Option<(perfopt::PerfOutcome, f64)> = None;

    for (wi, w) in engine.stats.workloads.iter().take(n_workloads).enumerate() {
        let g = w.gemm;
        let t0 = Timer::start();
        let ours = perfopt::diffaxe_perfopt(&engine, &g, n_designs, 200 + wi as u32)?;
        times[4] += t0.elapsed_s();

        let t1 = Timer::start();
        let a1 = engine.airchitect_v1(&g)?;
        times[0] += t1.elapsed_s();
        let t2 = Timer::start();
        let a2 = engine.airchitect_v2(&g)?;
        times[1] += t2.elapsed_s();
        // VAESA: latent BO minimizing runtime == EDP search objective swap;
        // reuse latent BO with the runtime objective via edp helper on EDP —
        // for performance use lowest-runtime of its EDP search designs
        let t3 = Timer::start();
        let vaesa = edp::latent_bo_edp(&engine, &g, &bo_opts, 300 + wi as u64)?;
        times[2] += t3.elapsed_s();
        let (train_hw, train_cycles) = perfopt::best_in_training_space(&g);
        let _ = train_hw;

        norm_rt[0].push(runtime_of(&a1, &g) / ours.best_cycles);
        norm_rt[1].push(runtime_of(&a2, &g) / ours.best_cycles);
        norm_rt[2].push(runtime_of(&vaesa.best_hw, &g) / ours.best_cycles);
        norm_rt[3].push(train_cycles / ours.best_cycles);
        if ours.best_cycles < train_cycles {
            beat_training += 1;
        }
        if example.is_none() {
            example = Some((ours, train_cycles));
        }
    }

    let mut t = Table::new(&["Method", "Normalized runtime (down, 1.0 = DiffAxE)", "Search time (s)"]);
    let names = ["AIRCHITECT", "AIRCHITECT v2", "VAESA (latent BO)", "Training-set best"];
    for (i, n) in names.iter().enumerate() {
        let time = if i < 3 { fnum(times[i] / n_workloads as f64) } else { "-".into() };
        t.row(&[n.to_string(), fnum(geomean(&norm_rt[i])), time]);
    }
    t.row(&["DiffAxE (ours)".into(), "1.000".into(), fnum(times[4] / n_workloads as f64)]);
    println!("{}", t.render());
    println!(
        "paper-shape checks: DiffAxE beats training data on {beat_training}/{n_workloads} \
         workloads (Fig 19); AIRCHITECT ratio {:.2} (paper 2.51x), v2 {:.2} (paper 1.16x), \
         VAESA {:.2} (paper 1.10x)",
        geomean(&norm_rt[0]),
        geomean(&norm_rt[1]),
        geomean(&norm_rt[2]),
    );

    // Table V style detail for the first workload
    if let Some((ours, train_cycles)) = example {
        let g = engine.stats.workloads[0].gemm;
        let (train_hw, _) = perfopt::best_in_training_space(&g);
        println!("\nTable V analogue for {g}:");
        let mut tv = Table::new(&["Parameter", "DiffAxE", "Training best"]);
        tv.row(&["R x C".into(), format!("{}x{}", ours.best_hw.r, ours.best_hw.c),
                 format!("{}x{}", train_hw.r, train_hw.c)]);
        tv.row(&["IPSz (kB)".into(), fnum(ours.best_hw.ip_kb()), fnum(train_hw.ip_kb())]);
        tv.row(&["WTSz (kB)".into(), fnum(ours.best_hw.wt_kb()), fnum(train_hw.wt_kb())]);
        tv.row(&["OPSz (kB)".into(), fnum(ours.best_hw.op_kb()), fnum(train_hw.op_kb())]);
        tv.row(&["BW (B/cyc)".into(), ours.best_hw.bw.to_string(), train_hw.bw.to_string()]);
        tv.row(&["Loop order".into(), ours.best_hw.loop_order.name().into(),
                 train_hw.loop_order.name().into()]);
        tv.row(&["Runtime (cycles)".into(), fnum(ours.best_cycles), fnum(train_cycles)]);
        println!("{}", tv.render());
    }
    Ok(())
}
