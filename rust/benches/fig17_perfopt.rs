//! Fig 17 / Fig 19 / Table V: DSE for performance — normalized runtime and
//! search time vs AIRCHITECT v1/v2, VAESA (latent BO), and the best
//! configuration in the training data, every searcher selected by
//! `OptimizerKind` through one `Session`.
//!
//! Paper shape: DiffAxE fastest designs (lowest normalized runtime), large
//! search-time advantage over VAESA, and generated designs beating the best
//! training-set configuration (Fig 19) with bigger arrays + weight buffers
//! (Table V).

use diffaxe::baselines::BoOptions;
use diffaxe::dse::{perfopt, runtime_of, Budget, Objective, OptimizerKind, Session};
use diffaxe::models::DiffAxE;
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::stats::geomean;
use diffaxe::util::table::{fnum, Table};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    banner("Fig 17/19, Table V", "DSE for performance optimization");
    let dir = Path::new("artifacts");
    if !DiffAxE::artifacts_present(dir) {
        println!("SKIP: run `make artifacts` first");
        return Ok(());
    }
    let mut session = Session::load(dir)?;
    let scale = BenchScale::from_env();
    let stats = session.engine().unwrap().stats.clone();
    let n_workloads = scale.pick(2, 6, stats.workloads.len());
    let n_designs = scale.pick(32, 128, 1000);
    session.bo_opts = BoOptions {
        n_init: scale.pick(6, 10, 16),
        budget: scale.pick(15, 40, 150),
        pool: scale.pick(64, 200, 512),
        ..Default::default()
    };
    let bo_evals = session.bo_opts.budget;

    let mut norm_rt = vec![vec![]; 4]; // air1, air2, vaesa, train-best (normalized to DiffAxE)
    let mut times = [0.0f64; 5];
    let mut beat_training = 0usize;
    let mut example: Option<(diffaxe::dse::DesignReport, f64)> = None;

    for (wi, w) in stats.workloads.iter().take(n_workloads).enumerate() {
        let g = w.gemm;
        let perf = Objective::MaxPerf { g };
        let seed = 200 + wi as u64;

        let ours =
            session.search(OptimizerKind::DiffAxE, &perf, &Budget::evals(n_designs), seed)?;
        let best_cycles = ours.best_score();
        times[4] += ours.search_time_s;

        let a1 = session.search(OptimizerKind::AirchitectV1, &perf, &Budget::evals(1), seed)?;
        times[0] += a1.search_time_s;
        let a2 = session.search(OptimizerKind::AirchitectV2, &perf, &Budget::evals(1), seed)?;
        times[1] += a2.search_time_s;
        // VAESA: latent BO on the EDP objective; for performance read the
        // runtime of its lowest-EDP design (the paper's protocol)
        let vaesa = session.search(
            OptimizerKind::LatentBo,
            &Objective::MinEdp { g },
            &Budget::evals(bo_evals),
            300 + wi as u64,
        )?;
        times[2] += vaesa.search_time_s;
        let (_, train_cycles) = perfopt::best_in_training_space(&g);

        norm_rt[0].push(a1.best_score() / best_cycles);
        norm_rt[1].push(a2.best_score() / best_cycles);
        norm_rt[2].push(runtime_of(&vaesa.best().unwrap().hw, &g) / best_cycles);
        norm_rt[3].push(train_cycles / best_cycles);
        if best_cycles < train_cycles {
            beat_training += 1;
        }
        if example.is_none() {
            example = Some((*ours.best().unwrap(), train_cycles));
        }
    }

    let mut t = Table::new(&["Method", "Normalized runtime (down, 1.0 = DiffAxE)", "Search time (s)"]);
    let names = ["AIRCHITECT", "AIRCHITECT v2", "VAESA (latent BO)", "Training-set best"];
    for (i, n) in names.iter().enumerate() {
        let time = if i < 3 { fnum(times[i] / n_workloads as f64) } else { "-".into() };
        t.row(&[n.to_string(), fnum(geomean(&norm_rt[i])), time]);
    }
    t.row(&["DiffAxE (ours)".into(), "1.000".into(), fnum(times[4] / n_workloads as f64)]);
    println!("{}", t.render());
    println!(
        "paper-shape checks: DiffAxE beats training data on {beat_training}/{n_workloads} \
         workloads (Fig 19); AIRCHITECT ratio {:.2} (paper 2.51x), v2 {:.2} (paper 1.16x), \
         VAESA {:.2} (paper 1.10x)",
        geomean(&norm_rt[0]),
        geomean(&norm_rt[1]),
        geomean(&norm_rt[2]),
    );

    // Table V style detail for the first workload
    if let Some((best, train_cycles)) = example {
        let g = stats.workloads[0].gemm;
        let (train_hw, _) = perfopt::best_in_training_space(&g);
        println!("\nTable V analogue for {g}:");
        let mut tv = Table::new(&["Parameter", "DiffAxE", "Training best"]);
        tv.row(&["R x C".into(), format!("{}x{}", best.hw.r, best.hw.c),
                 format!("{}x{}", train_hw.r, train_hw.c)]);
        tv.row(&["IPSz (kB)".into(), fnum(best.hw.ip_kb()), fnum(train_hw.ip_kb())]);
        tv.row(&["WTSz (kB)".into(), fnum(best.hw.wt_kb()), fnum(train_hw.wt_kb())]);
        tv.row(&["OPSz (kB)".into(), fnum(best.hw.op_kb()), fnum(train_hw.op_kb())]);
        tv.row(&["BW (B/cyc)".into(), best.hw.bw.to_string(), train_hw.bw.to_string()]);
        tv.row(&["Loop order".into(), best.hw.loop_order.name().into(),
                 train_hw.loop_order.name().into()]);
        tv.row(&["Runtime (cycles)".into(), fnum(best.cycles), fnum(train_cycles)]);
        println!("{}", tv.render());
    }
    Ok(())
}
