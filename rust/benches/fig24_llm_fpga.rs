//! Fig 24: EDP and runtime of BERT-base prefill/decode on the VU13P FPGA —
//! fixed architectures vs DOSA vs DiffAxE.
//!
//! Paper shape: DiffAxE lowest EDP in both stages (7.5x / 8x better than
//! DOSA on the paper's testbed).

use diffaxe::baselines::FixedArch;
use diffaxe::dse::llm::{diffaxe_llm, dosa_llm, fixed_llm, Platform};
use diffaxe::models::DiffAxE;
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::{llm::DEFAULT_SEQ, LlmModel, Stage};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    banner("Fig 24", "BERT-base EDP/runtime on VU13P FPGA");
    let dir = Path::new("artifacts");
    if !DiffAxE::artifacts_present(dir) {
        println!("SKIP: run `make artifacts` first");
        return Ok(());
    }
    let engine = DiffAxE::load(dir)?;
    let scale = BenchScale::from_env();
    let n = scale.pick(8, 32, 128);
    let platform = Platform::FpgaVu13p;

    let mut t = Table::new(&["Stage", "Architecture", "Runtime (cycles)", "EDP (uJ-cyc)", "EDP / DiffAxE"]);
    for stage in Stage::ALL {
        let (ours, _) =
            diffaxe_llm(&engine, LlmModel::BertBase, stage, DEFAULT_SEQ, n, platform, 42)?;
        let base = ours.energy.edp;
        for arch in FixedArch::ALL {
            let e = fixed_llm(arch, LlmModel::BertBase, stage, DEFAULT_SEQ, platform);
            t.row(&[
                stage.name().to_string(),
                arch.name().to_string(),
                fnum(e.sim.cycles as f64),
                fnum(e.energy.edp),
                fnum(e.energy.edp / base),
            ]);
        }
        let (dosa, _) = dosa_llm(LlmModel::BertBase, stage, DEFAULT_SEQ, platform, 17);
        t.row(&[
            stage.name().to_string(),
            "DOSA".to_string(),
            fnum(dosa.sim.cycles as f64),
            fnum(dosa.energy.edp),
            fnum(dosa.energy.edp / base),
        ]);
        t.row(&[
            stage.name().to_string(),
            "DiffAxE".to_string(),
            fnum(ours.sim.cycles as f64),
            fnum(base),
            "1.00".to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("paper-shape check: DiffAxE lowest EDP in both stages (paper: 7.5x/8x vs DOSA)");
    Ok(())
}
