//! Fig 24: EDP and runtime of BERT-base prefill/decode on the VU13P FPGA —
//! fixed architectures vs DOSA vs DiffAxE, all through the `Optimizer`
//! trait on `Objective::LlmEdp`.
//!
//! Paper shape: DiffAxE lowest EDP in both stages (7.5x / 8x better than
//! DOSA on the paper's testbed).

use diffaxe::baselines::{FixedArch, GdOptions};
use diffaxe::dse::llm::Platform;
use diffaxe::dse::{Budget, Objective, OptimizerKind, Session};
use diffaxe::models::DiffAxE;
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::{llm::DEFAULT_SEQ, LlmModel, Stage};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    banner("Fig 24", "BERT-base EDP/runtime on VU13P FPGA");
    let dir = Path::new("artifacts");
    if !DiffAxE::artifacts_present(dir) {
        println!("SKIP: run `make artifacts` first");
        return Ok(());
    }
    let mut session = Session::load(dir)?;
    session.gd_opts = GdOptions { steps: 30, restarts: 3, ..Default::default() };
    let scale = BenchScale::from_env();
    let n = scale.pick(8, 32, 128);
    let platform = Platform::FpgaVu13p;
    let gd_budget = Budget::evals(scale.pick(600, 1600, 5000));

    let mut t = Table::new(&["Stage", "Architecture", "Runtime (cycles)", "EDP (uJ-cyc)", "EDP / DiffAxE"]);
    for stage in Stage::ALL {
        let obj =
            Objective::LlmEdp { model: LlmModel::BertBase, stage, seq: DEFAULT_SEQ, platform };
        let ours = session.search(
            OptimizerKind::DiffAxE,
            &obj,
            &Budget::default().with_per_class(n),
            42,
        )?;
        let base = ours.best().unwrap().edp;
        for arch in FixedArch::ALL {
            let e = session
                .search(OptimizerKind::Fixed(arch), &obj, &Budget::evals(1), 0)?;
            let d = *e.best().unwrap();
            t.row(&[
                stage.name().to_string(),
                arch.name().to_string(),
                fnum(d.cycles),
                fnum(d.edp),
                fnum(d.edp / base),
            ]);
        }
        let dosa = session.search(OptimizerKind::DosaGd, &obj, &gd_budget, 17)?;
        let d = *dosa.best().unwrap();
        t.row(&[
            stage.name().to_string(),
            "DOSA".to_string(),
            fnum(d.cycles),
            fnum(d.edp),
            fnum(d.edp / base),
        ]);
        let b = *ours.best().unwrap();
        t.row(&[
            stage.name().to_string(),
            "DiffAxE".to_string(),
            fnum(b.cycles),
            fnum(base),
            "1.00".to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("eval-cache: {}", session.cache_stats());
    println!("paper-shape check: DiffAxE lowest EDP in both stages (paper: 7.5x/8x vs DOSA)");
    Ok(())
}
