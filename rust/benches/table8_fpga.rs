//! Table VIII / Fig 23: FPGA resource utilization and power on the Xilinx
//! Virtex UltraScale+ VU13P for the five architectures of §VI.
//!
//! The resource model is calibrated to reproduce the paper's utilization
//! rows *exactly* (see energy::fpga); this bench prints both the paper's
//! fixed rows and the rows for the designs our DOSA/DiffAxE searches found
//! (both searches run through the `Optimizer` trait).

use diffaxe::baselines::{FixedArch, GdOptions};
use diffaxe::design_space::{HwConfig, LoopOrder};
use diffaxe::dse::llm::Platform;
use diffaxe::dse::{Budget, Objective, OptimizerKind, Session};
use diffaxe::energy::fpga;
use diffaxe::models::DiffAxE;
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::{llm::DEFAULT_SEQ, LlmModel, Stage};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    banner("Table VIII / Fig 23", "VU13P resource utilization + power (BERT-base prefill)");

    // paper Table VII row designs for DOSA and DiffAxE (exact reproduction
    // of the published utilization numbers)
    let paper_rows: Vec<(&str, HwConfig)> = vec![
        ("Eyeriss", FixedArch::Eyeriss.config()),
        ("ShiDianNao", FixedArch::ShiDianNao.config()),
        ("NVDLA", FixedArch::Nvdla.config()),
        ("DOSA (paper VII)", HwConfig::new_kb(128, 128, 128.0, 128.0, 64.0, 32, LoopOrder::Mnk)),
        ("DiffAxE (paper VII)", HwConfig::new_kb(128, 63, 1024.0, 4.0, 8.5, 32, LoopOrder::Nmk)),
    ];

    let mut t = Table::new(&["Architecture", "#DSP", "#LUT", "#FF", "#BRAM", "#URAM", "Power (W)"]);
    let g = diffaxe::workload::Gemm::new(128, 768, 2304); // BERT-base prefill QKV proxy
    for (name, hw) in &paper_rows {
        let r = fpga::resources(hw);
        let sim = diffaxe::sim::simulate(hw, &g);
        let e = fpga::evaluate(hw, &sim);
        t.row(&[
            name.to_string(),
            r.dsp.to_string(),
            r.lut.to_string(),
            r.ff.to_string(),
            r.bram.to_string(),
            r.uram.to_string(),
            fnum(e.power_w),
        ]);
    }
    println!("{}", t.render());
    println!(
        "paper rows (Table VIII): Eyeriss 84/45696/71544/10/6, ShiDianNao 128/.../26/0, \
         NVDLA 512/.../31/15, DOSA 8192/360448/540672/23/8, DiffAxE 4032/232408/352112/11/29"
    );

    // rows for the designs found by OUR searches (freshly optimized)
    let dir = Path::new("artifacts");
    if DiffAxE::artifacts_present(dir) {
        let mut session = Session::load(dir)?;
        session.gd_opts = GdOptions { steps: 30, restarts: 3, ..Default::default() };
        let scale = BenchScale::from_env();
        let n = scale.pick(8, 32, 128);
        let obj = Objective::LlmEdp {
            model: LlmModel::BertBase,
            stage: Stage::Prefill,
            seq: DEFAULT_SEQ,
            platform: Platform::FpgaVu13p,
        };
        let ours = session.search(
            OptimizerKind::DiffAxE,
            &obj,
            &Budget::default().with_per_class(n),
            42,
        )?;
        let dosa = session.search(
            OptimizerKind::DosaGd,
            &obj,
            &Budget::evals(scale.pick(600, 1600, 5000)),
            17,
        )?;
        let mut t2 = Table::new(&["Found design", "#DSP", "#BRAM", "#URAM", "Power (W)"]);
        for (name, hw) in
            [("DOSA (ours)", dosa.best().unwrap().hw), ("DiffAxE (ours)", ours.best().unwrap().hw)]
        {
            let r = fpga::resources(&hw);
            let e = fixed_power(&hw);
            t2.row(&[name.to_string(), r.dsp.to_string(), r.bram.to_string(),
                     r.uram.to_string(), fnum(e)]);
        }
        println!("{}", t2.render());
    } else {
        println!("(artifacts missing: skipping freshly-searched designs)");
    }
    Ok(())
}

fn fixed_power(hw: &HwConfig) -> f64 {
    let g = diffaxe::workload::Gemm::new(128, 768, 2304);
    let sim = diffaxe::sim::simulate(hw, &g);
    fpga::evaluate(hw, &sim).power_w
}
