//! Micro-benchmark of the coordinator service: request latency and
//! throughput with and without cross-request batching, plus sampler batch
//! occupancy. The paper's headline — milliseconds per generated
//! configuration — is measured here end to end (request → diffusion →
//! decode → rounding → simulation → reply).

use diffaxe::coordinator::{Request, Response, Service, ServiceConfig};
use diffaxe::models::DiffAxE;
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::stats::Timer;
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::Gemm;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    banner("micro:coordinator", "end-to-end generation service latency/throughput");
    if !DiffAxE::artifacts_present(Path::new("artifacts")) {
        println!("SKIP: run `make artifacts` first");
        return Ok(());
    }
    let svc = Service::start(ServiceConfig::new("artifacts"))?;
    let scale = BenchScale::from_env();
    let g = Gemm::new(128, 768, 2304);

    let mut t = Table::new(&["pattern", "requests", "designs", "wall (s)", "ms/design", "designs/s"]);

    // (1) one large request — full batches
    let n_large = scale.pick(64, 256, 1024);
    let timer = Timer::start();
    let resp = svc.handle().request(Request::GenerateRuntime { g, target_cycles: 1e6, n: n_large });
    let dt = timer.elapsed_s();
    let designs = match resp {
        Response::Designs(d) => d.len(),
        other => panic!("{other:?}"),
    };
    t.row(&[
        "single bulk request".into(),
        "1".into(),
        designs.to_string(),
        fnum(dt),
        fnum(dt * 1e3 / designs as f64),
        fnum(designs as f64 / dt),
    ]);

    // (2) many small concurrent requests — exercises continuous batching
    let n_req = scale.pick(8, 24, 64);
    let per_req = 8;
    let timer = Timer::start();
    let rxs: Vec<_> = (0..n_req)
        .map(|i| {
            svc.handle().submit(Request::GenerateRuntime {
                g,
                target_cycles: 5e5 + 1e5 * i as f64,
                n: per_req,
            })
        })
        .collect();
    let mut total = 0;
    for rx in rxs {
        if let Response::Designs(d) = rx.recv().unwrap() {
            total += d.len();
        }
    }
    let dt = timer.elapsed_s();
    t.row(&[
        format!("{n_req} concurrent x{per_req}"),
        n_req.to_string(),
        total.to_string(),
        fnum(dt),
        fnum(dt * 1e3 / total as f64),
        fnum(total as f64 / dt),
    ]);
    println!("{}", t.render());

    let snap = svc.handle().metrics().snapshot();
    println!("service metrics: {snap}");
    println!(
        "paper-shape check: ms/design in the low single digits (paper: 1.83 ms/config on V100)"
    );
    Ok(())
}
