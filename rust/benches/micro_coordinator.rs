//! Micro-benchmark of the coordinator service: request latency and
//! throughput with and without cross-request batching, plus sampler batch
//! occupancy. The paper's headline — milliseconds per generated
//! configuration — is measured here end to end (request → diffusion →
//! decode → rounding → batched simulation → reply), now through the
//! generic v2 `search` request.

use diffaxe::coordinator::{Request, Response, SearchRequest, Service, ServiceConfig};
use diffaxe::dse::{Budget, Objective, OptimizerKind};
use diffaxe::models::DiffAxE;
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::stats::Timer;
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::Gemm;
use std::path::Path;

fn generate(g: Gemm, target_cycles: f64, n: usize) -> Request {
    Request::Search(SearchRequest::new(
        Objective::Runtime { g, target_cycles },
        Budget::evals(n),
        OptimizerKind::DiffAxE,
    ))
}

fn main() -> anyhow::Result<()> {
    banner("micro:coordinator", "end-to-end generation service latency/throughput");
    if !DiffAxE::artifacts_present(Path::new("artifacts")) {
        println!("SKIP: run `make artifacts` first");
        return Ok(());
    }
    let svc = Service::start(ServiceConfig::new("artifacts"))?;
    let scale = BenchScale::from_env();
    let g = Gemm::new(128, 768, 2304);

    let mut t = Table::new(&["pattern", "requests", "designs", "wall (s)", "ms/design", "designs/s"]);

    // (1) one large request — full batches
    let n_large = scale.pick(64, 256, 1024);
    let timer = Timer::start();
    let resp = svc.handle().request(generate(g, 1e6, n_large));
    let dt = timer.elapsed_s();
    let designs = match resp {
        Response::Outcome(o) => o.evals,
        other => panic!("{other:?}"),
    };
    t.row(&[
        "single bulk request".into(),
        "1".into(),
        designs.to_string(),
        fnum(dt),
        fnum(dt * 1e3 / designs as f64),
        fnum(designs as f64 / dt),
    ]);

    // (2) many small concurrent requests — exercises continuous batching
    let n_req = scale.pick(8, 24, 64);
    let per_req = 8;
    let timer = Timer::start();
    let rxs: Vec<_> = (0..n_req)
        .map(|i| svc.handle().submit(generate(g, 5e5 + 1e5 * i as f64, per_req)))
        .collect();
    let mut total = 0;
    for rx in rxs {
        if let Response::Outcome(o) = rx.recv().unwrap() {
            total += o.evals;
        }
    }
    let dt = timer.elapsed_s();
    t.row(&[
        format!("{n_req} concurrent x{per_req}"),
        n_req.to_string(),
        total.to_string(),
        fnum(dt),
        fnum(dt * 1e3 / total as f64),
        fnum(total as f64 / dt),
    ]);

    // (3) one Batch request carrying several searches in one round-trip
    let n_batch = scale.pick(4, 8, 16);
    let timer = Timer::start();
    let resp = svc.handle().request(Request::Batch(
        (0..n_batch)
            .map(|i| {
                SearchRequest::new(
                    Objective::Runtime { g, target_cycles: 4e5 * (i + 1) as f64 },
                    Budget::evals(per_req),
                    OptimizerKind::DiffAxE,
                )
            })
            .collect(),
    ));
    let dt = timer.elapsed_s();
    let designs = match resp {
        Response::Batch(outs) => outs.iter().map(|o| o.evals).sum::<usize>(),
        other => panic!("{other:?}"),
    };
    t.row(&[
        format!("batch request x{n_batch}"),
        "1".into(),
        designs.to_string(),
        fnum(dt),
        fnum(dt * 1e3 / designs as f64),
        fnum(designs as f64 / dt),
    ]);
    println!("{}", t.render());

    let snap = svc.handle().metrics().snapshot();
    println!("service metrics: {snap}");
    println!(
        "paper-shape check: ms/design in the low single digits (paper: 1.83 ms/config on V100)"
    );
    Ok(())
}
