//! Fig 22 / Table VII: LLM inference EDP on the 32 nm ASIC —
//! Eyeriss / ShiDianNao / NVDLA / DOSA vs DiffAxE across BERT-base,
//! OPT-350M and LLaMA-2-7B, prefill (seq 128) and decode.
//!
//! Paper shape: DiffAxE lowest EDP everywhere; the gap vs fixed
//! architectures is largest in prefill (PE-array flexibility); DiffAxE
//! > 2x better than DOSA.

use diffaxe::baselines::FixedArch;
use diffaxe::dse::llm::{diffaxe_llm, dosa_llm, fixed_llm, Platform};
use diffaxe::models::DiffAxE;
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::{llm::DEFAULT_SEQ, LlmModel, Stage};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    banner("Fig 22 / Table VII", "LLM EDP on 32nm ASIC");
    let dir = Path::new("artifacts");
    if !DiffAxE::artifacts_present(dir) {
        println!("SKIP: run `make artifacts` first");
        return Ok(());
    }
    let engine = DiffAxE::load(dir)?;
    let scale = BenchScale::from_env();
    let n_per_layer = scale.pick(8, 32, 128);
    let platform = Platform::Asic32nm;

    let mut t = Table::new(&[
        "Model", "Stage", "Eyeriss", "ShiDianNao", "NVDLA", "DOSA", "DiffAxE",
        "(EDP normalized to DiffAxE)",
    ]);
    let mut dosa_ratios = Vec::new();
    let mut table7: Option<String> = None;
    for model in LlmModel::ALL {
        for stage in Stage::ALL {
            let (ours, _time) =
                diffaxe_llm(&engine, model, stage, DEFAULT_SEQ, n_per_layer, platform, 42)?;
            let (dosa, _t) = dosa_llm(model, stage, DEFAULT_SEQ, platform, 17);
            let fixed: Vec<f64> = FixedArch::ALL
                .iter()
                .map(|&a| fixed_llm(a, model, stage, DEFAULT_SEQ, platform).energy.edp)
                .collect();
            let base = ours.energy.edp;
            dosa_ratios.push(dosa.energy.edp / base);
            t.row(&[
                model.name().to_string(),
                stage.name().to_string(),
                fnum(fixed[0] / base),
                fnum(fixed[1] / base),
                fnum(fixed[2] / base),
                fnum(dosa.energy.edp / base),
                "1.00".into(),
                format!("abs {:.2e} uJ-cyc", base),
            ]);
            if model == LlmModel::BertBase && table7.is_none() {
                // Table VII analogue: config + per-layer orders
                let orders: Vec<&str> =
                    ours.cfg.orders.iter().map(|o| o.name()).collect();
                table7 = Some(format!(
                    "Table VII analogue (BERT-base {}): DiffAxE {} orders [{}] runtime {:.3e} \
                     cycles edp {:.3e} | DOSA {} runtime {:.3e} edp {:.3e}",
                    stage.name(),
                    ours.cfg.base,
                    orders.join(","),
                    ours.sim.cycles as f64,
                    ours.energy.edp,
                    dosa.cfg.base,
                    dosa.sim.cycles as f64,
                    dosa.energy.edp
                ));
            }
        }
    }
    println!("{}", t.render());
    if let Some(s) = table7 {
        println!("{s}");
    }
    let geo = diffaxe::util::stats::geomean(&dosa_ratios);
    println!(
        "paper-shape checks: DOSA/DiffAxE EDP geo-mean {:.2}x (paper: >2x in every scenario, \
         3.37x avg); all fixed archs above 1.0: {}",
        geo,
        dosa_ratios.iter().all(|&r| r > 0.0)
    );
    Ok(())
}
