//! Fig 22 / Table VII: LLM inference EDP on the 32 nm ASIC —
//! Eyeriss / ShiDianNao / NVDLA / DOSA vs DiffAxE across BERT-base,
//! OPT-350M and LLaMA-2-7B, prefill (seq 128) and decode — one
//! `Objective::LlmEdp` served by every optimizer kind.
//!
//! Paper shape: DiffAxE lowest EDP everywhere; the gap vs fixed
//! architectures is largest in prefill (PE-array flexibility); DiffAxE
//! > 2x better than DOSA.

use diffaxe::baselines::{FixedArch, GdOptions};
use diffaxe::dse::llm::{eval_model, Platform};
use diffaxe::dse::{Budget, Objective, OptimizerKind, Session};
use diffaxe::models::DiffAxE;
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::{llm::DEFAULT_SEQ, LlmModel, Stage};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    banner("Fig 22 / Table VII", "LLM EDP on 32nm ASIC");
    let dir = Path::new("artifacts");
    if !DiffAxE::artifacts_present(dir) {
        println!("SKIP: run `make artifacts` first");
        return Ok(());
    }
    let mut session = Session::load(dir)?;
    session.gd_opts = GdOptions { steps: 30, restarts: 3, ..Default::default() };
    let scale = BenchScale::from_env();
    let n_per_layer = scale.pick(8, 32, 128);
    let platform = Platform::Asic32nm;
    let gen_budget = Budget::default().with_per_class(n_per_layer);
    let gd_budget = Budget::evals(scale.pick(600, 1600, 5000));

    let mut t = Table::new(&[
        "Model", "Stage", "Eyeriss", "ShiDianNao", "NVDLA", "DOSA", "DiffAxE",
        "(EDP normalized to DiffAxE)",
    ]);
    let mut dosa_ratios = Vec::new();
    let mut table7: Option<String> = None;
    for model in LlmModel::ALL {
        for stage in Stage::ALL {
            let obj = Objective::LlmEdp { model, stage, seq: DEFAULT_SEQ, platform };
            let ours = session.search(OptimizerKind::DiffAxE, &obj, &gen_budget, 42)?;
            let dosa = session.search(OptimizerKind::DosaGd, &obj, &gd_budget, 17)?;
            let fixed: Vec<f64> = FixedArch::ALL
                .iter()
                .map(|&a| {
                    session
                        .search(OptimizerKind::Fixed(a), &obj, &Budget::evals(1), 0)
                        .map(|o| o.best().unwrap().edp)
                })
                .collect::<anyhow::Result<_>>()?;
            let base = ours.best().unwrap().edp;
            let dosa_edp = dosa.best().unwrap().edp;
            dosa_ratios.push(dosa_edp / base);
            t.row(&[
                model.name().to_string(),
                stage.name().to_string(),
                fnum(fixed[0] / base),
                fnum(fixed[1] / base),
                fnum(fixed[2] / base),
                fnum(dosa_edp / base),
                "1.00".into(),
                format!("abs {:.2e} uJ-cyc", base),
            ]);
            if model == LlmModel::BertBase && table7.is_none() {
                // Table VII analogue: re-derive the full sequence config
                // (per-layer loop orders) for the winning base designs
                let ours_seq =
                    eval_model(&ours.best().unwrap().hw, model, stage, DEFAULT_SEQ, platform);
                let dosa_seq =
                    eval_model(&dosa.best().unwrap().hw, model, stage, DEFAULT_SEQ, platform);
                let orders: Vec<&str> =
                    ours_seq.cfg.orders.iter().map(|o| o.name()).collect();
                table7 = Some(format!(
                    "Table VII analogue (BERT-base {}): DiffAxE {} orders [{}] runtime {:.3e} \
                     cycles edp {:.3e} | DOSA {} runtime {:.3e} edp {:.3e}",
                    stage.name(),
                    ours_seq.cfg.base,
                    orders.join(","),
                    ours_seq.sim.cycles as f64,
                    ours_seq.energy.edp,
                    dosa_seq.cfg.base,
                    dosa_seq.sim.cycles as f64,
                    dosa_seq.energy.edp
                ));
            }
        }
    }
    println!("{}", t.render());
    if let Some(s) = table7 {
        println!("{s}");
    }
    println!("eval-cache: {}", session.cache_stats());
    let geo = diffaxe::util::stats::geomean(&dosa_ratios);
    println!(
        "paper-shape checks: DOSA/DiffAxE EDP geo-mean {:.2}x (paper: >2x in every scenario, \
         3.37x avg); all fixed archs above 1.0: {}",
        geo,
        dosa_ratios.iter().all(|&r| r > 0.0)
    );
    Ok(())
}
