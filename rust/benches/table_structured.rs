//! Structured DSE (§V): per-segment heterogeneous search over the
//! O(10^17) joint space — best whole-model EDP and search throughput for
//! DiffAxE (per-segment conditioning) vs the DOSA coarse-GD, vanilla-BO
//! and random-search baselines, all on the same evaluation budget.
//!
//! Paper shape: DiffAxE finds lower EDP than DOSA and random while
//! evaluating orders of magnitude more candidates per second than BO
//! (§V: 9.8% lower EDP, 145.6×/1312× faster search).
//!
//! **Hermetic**: runs on the mock engine when `artifacts/` is absent, so
//! CI tracks the perf trajectory via `BENCH_structured.json` on every
//! push; real artifacts are the opt-in superset.

use diffaxe::baselines::{BoOptions, GdOptions};
use diffaxe::dse::llm::Platform;
use diffaxe::dse::{Budget, Objective, OptimizerKind, SearchCtx, Session, StructuredSpec};
use diffaxe::models::DiffAxE;
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::json::Json;
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::{LlmModel, Stage};
use std::collections::BTreeMap;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    banner("Table §V", "structured DSE — per-segment heterogeneous configs");
    let scale = BenchScale::from_env();
    let dir = Path::new("artifacts");
    let mut session = if DiffAxE::artifacts_present(dir) {
        println!("engine: artifacts/");
        Session::load(dir)?
    } else {
        println!("engine: hermetic mock (artifacts/ absent)");
        Session::mock()
    };
    let evals = scale.pick(48, 256, 1500);
    session.bo_opts = BoOptions {
        n_init: scale.pick(6, 10, 16),
        budget: scale.pick(20, 48, 150),
        pool: scale.pick(64, 128, 256),
        ..Default::default()
    };
    session.gd_opts = GdOptions {
        steps: scale.pick(8, 16, 40),
        restarts: scale.pick(1, 2, 4),
        ..Default::default()
    };
    let spec = StructuredSpec::new(LlmModel::BertBase, Stage::Prefill, 128, Platform::Asic32nm, 3);
    let obj = Objective::StructuredEdp { spec };
    println!("space: ~{:.2e} joint design points, {} segments", spec.cardinality(), spec.segments);

    struct Row {
        kind: OptimizerKind,
        name: &'static str,
        budget: Budget,
        best_edp: f64,
        time_s: f64,
        evals: usize,
    }
    let mut rows = vec![
        Row {
            kind: OptimizerKind::RandomSearch,
            name: "Random Search",
            budget: Budget::evals(evals),
            best_edp: 0.0,
            time_s: 0.0,
            evals: 0,
        },
        Row {
            kind: OptimizerKind::VanillaBo,
            name: "Vanilla BO",
            budget: Budget::evals(session.bo_opts.budget),
            best_edp: 0.0,
            time_s: 0.0,
            evals: 0,
        },
        Row {
            kind: OptimizerKind::DosaGd,
            name: "DOSA (coarse GD)",
            budget: Budget::evals(evals),
            best_edp: 0.0,
            time_s: 0.0,
            evals: 0,
        },
        Row {
            kind: OptimizerKind::DiffAxE,
            name: "DiffAxE (joint+learned-cuts)",
            budget: Budget::evals(evals),
            best_edp: 0.0,
            time_s: 0.0,
            evals: 0,
        },
    ];
    let seed = 11u64;
    for row in &mut rows {
        let out = session.search(row.kind, &obj, &row.budget, seed)?;
        row.best_edp = out.best_score();
        row.time_s = out.search_time_s;
        row.evals = out.evals;
    }
    let rand_best = rows[0].best_edp;
    // the pre-learned-segmentation reference: independently-conditioned
    // per-segment pools zipped over the fixed partition — the baseline the
    // jointly-conditioned row is gated against
    let zip = {
        let engine = session.engine().expect("mock/loaded session always has an engine");
        diffaxe::dse::structured::search_engine_zip(
            engine,
            &SearchCtx::background(),
            &obj,
            &spec,
            &Budget::evals(evals),
            seed,
        )?
    };

    let mut t =
        Table::new(&["Method", "Best EDP (dn)", "SP vs random (up)", "cand/s (up)", "evals"]);
    let mut json: BTreeMap<String, Json> = BTreeMap::new();
    json.insert("evals_budget".into(), Json::Num(evals as f64));
    json.insert("segments".into(), Json::Num(spec.segments as f64));
    json.insert("space_cardinality".into(), Json::Num(spec.cardinality()));
    for row in &rows {
        let sp = rand_best / row.best_edp;
        let cps = row.evals as f64 / row.time_s.max(1e-9);
        t.row(&[
            row.name.to_string(),
            fnum(row.best_edp),
            fnum(sp),
            fnum(cps),
            row.evals.to_string(),
        ]);
        let key = row.kind.name().replace('-', "_");
        json.insert(format!("structured_sp_{key}"), Json::Num(sp));
        json.insert(format!("structured_cps_{key}"), Json::Num(cps));
        json.insert(format!("structured_best_edp_{key}"), Json::Num(row.best_edp));
    }
    {
        let best = zip.best_score();
        let cps = zip.evals as f64 / zip.search_time_s.max(1e-9);
        t.row(&[
            "DiffAxE (indep-zip)".to_string(),
            fnum(best),
            fnum(rand_best / best),
            fnum(cps),
            zip.evals.to_string(),
        ]);
        json.insert("structured_cps_zip".into(), Json::Num(cps));
        json.insert("structured_best_edp_zip".into(), Json::Num(best));
    }
    // issue-named gate aliases for the jointly-conditioned row: cps floors
    // as throughput, best-EDP floors with the lower-is-better direction
    let joint_cps = rows[3].evals as f64 / rows[3].time_s.max(1e-9);
    json.insert("structured_joint_cps".into(), Json::Num(joint_cps));
    json.insert("structured_joint_best_edp".into(), Json::Num(rows[3].best_edp));
    println!("{}", t.render());
    let sp_diffaxe = rand_best / rows[3].best_edp;
    let sp_dosa = rand_best / rows[2].best_edp;
    println!(
        "paper-shape checks: SP DiffAxE {sp_diffaxe:.3} > 1 ({}); SP DOSA {sp_dosa:.3} > 1 ({})",
        sp_diffaxe > 1.0,
        sp_dosa > 1.0
    );

    let out = Json::Obj(json).to_string();
    std::fs::write("BENCH_structured.json", &out).expect("write BENCH_structured.json");
    println!("wrote BENCH_structured.json: {out}");
    Ok(())
}
