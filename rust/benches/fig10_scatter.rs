//! Fig 10 + Fig 13 + Fig 1(b): performance–power landscape of the design
//! space — power span, runtime span per workload, and the DRAM-vs-compute
//! energy crossover.

use diffaxe::design_space::params::TrainingSpace;
use diffaxe::energy::{asic, cacti::DRAM_PJ_PER_BYTE};
use diffaxe::sim::simulate;
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::stats::{percentile, summarize};
use diffaxe::util::table::{fnum, Table};
use diffaxe::workload::Gemm;

fn main() {
    banner("Fig 10/13/1(b)", "power-performance scatter + runtime distributions");
    let scale = BenchScale::from_env();
    let stride = scale.pick(31, 7, 1);

    // Fig 10: (M,K,N) = (128, 4096, 8192) on the 32nm ASIC
    let g = Gemm::new(128, 4096, 8192);
    let mut powers = Vec::new();
    let mut cycles = Vec::new();
    let mut dram_fracs = Vec::new();
    for (i, hw) in TrainingSpace::enumerate().enumerate() {
        if i % stride != 0 {
            continue;
        }
        let s = simulate(&hw, &g);
        let e = asic::evaluate(&hw, &s);
        powers.push(e.power_w);
        cycles.push(s.cycles as f64);
        let e_dram = s.dram.total() as f64 * DRAM_PJ_PER_BYTE * 1e-6;
        dram_fracs.push((e_dram / e.e_dyn_uj, hw.macs() as f64));
    }
    let ps = summarize(&powers);
    let cs = summarize(&cycles);
    let mut t = Table::new(&["quantity", "min", "p50", "max"]);
    t.row(&["power (W)".into(), fnum(ps.min), fnum(percentile(&powers, 50.0)), fnum(ps.max)]);
    t.row(&["runtime (cycles)".into(), fnum(cs.min), fnum(percentile(&cycles, 50.0)), fnum(cs.max)]);
    println!("{}", t.render());
    println!("paper Fig 10: power 0.17-3.3 W over the same workload/space");

    // Fig 1(b): DRAM dominates at low compute density
    let small: Vec<f64> =
        dram_fracs.iter().filter(|(_, m)| *m <= 64.0).map(|(f, _)| *f).collect();
    let large: Vec<f64> =
        dram_fracs.iter().filter(|(_, m)| *m >= 4096.0).map(|(f, _)| *f).collect();
    println!(
        "DRAM share of dynamic energy: small arrays {:.2}, large arrays {:.2} \
         (paper Fig 1(b): DRAM dominates at low compute density): {}",
        summarize(&small).mean,
        summarize(&large).mean,
        summarize(&small).mean > summarize(&large).mean
    );

    // Fig 13: runtime ranges for the paper's two example workloads
    let mut t13 = Table::new(&["workload", "runtime min", "runtime max", "decades"]);
    for g in [Gemm::new(32, 32, 32), Gemm::new(512, 3072, 16384)] {
        let mut rts = Vec::new();
        for (i, hw) in TrainingSpace::enumerate().enumerate() {
            if i % scale.pick(63, 15, 3) != 0 {
                continue;
            }
            rts.push(simulate(&hw, &g).cycles as f64);
        }
        let s = summarize(&rts);
        t13.row(&[
            format!("{g}"),
            fnum(s.min),
            fnum(s.max),
            fnum((s.max / s.min).log10()),
        ]);
    }
    println!("{}", t13.render());
    println!("paper Fig 13: each workload spans ~3 decades of runtime");
}
