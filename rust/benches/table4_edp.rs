//! Table IV: EDP-oriented DSE — SP (= EDP_random / EDP_method, higher
//! better) and search time for random / vanilla BO / VAESA / DOSA /
//! Polaris / DiffAxE.
//!
//! Paper shape: SP(DiffAxE) > SP(VAESA) > 1 ≳ SP(vanilla BO) ≫ SP of the
//! coarse-space GD methods (DOSA, Polaris), with DiffAxE orders of
//! magnitude faster than the BO methods.

use diffaxe::baselines::{BoOptions, GdOptions};
use diffaxe::dse::edp;
use diffaxe::models::DiffAxE;
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::stats::geomean;
use diffaxe::util::table::{fnum, Table};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    banner("Table IV", "EDP-oriented DSE (SP vs random search)");
    let dir = Path::new("artifacts");
    if !DiffAxE::artifacts_present(dir) {
        println!("SKIP: run `make artifacts` first");
        return Ok(());
    }
    let engine = DiffAxE::load(dir)?;
    let scale = BenchScale::from_env();
    let n_workloads = scale.pick(2, 6, engine.stats.workloads.len());
    let n_per_class = scale.pick(8, 32, 1000); // paper: 1000
    let n_classes = engine.stats.n_power * engine.stats.n_perf;
    let budget = n_per_class * n_classes;
    let bo_opts = BoOptions {
        n_init: scale.pick(6, 10, 16),
        budget: scale.pick(15, 40, 150),
        pool: scale.pick(64, 200, 512),
        ..Default::default()
    };
    let gd_opts = GdOptions { steps: scale.pick(10, 25, 60), restarts: scale.pick(2, 3, 4), ..Default::default() };

    struct Agg {
        name: &'static str,
        space: &'static str,
        sps: Vec<f64>,
        time: f64,
    }
    let mut methods = vec![
        Agg { name: "Random Search", space: "O(10^17)", sps: vec![], time: 0.0 },
        Agg { name: "Vanilla BO", space: "O(10^17)", sps: vec![], time: 0.0 },
        Agg { name: "VAESA (latent BO)", space: "O(10^17)", sps: vec![], time: 0.0 },
        Agg { name: "DOSA (vanilla GD)", space: "~O(10^7)", sps: vec![], time: 0.0 },
        Agg { name: "Polaris (latent GD)", space: "~O(10^7)", sps: vec![], time: 0.0 },
        Agg { name: "DiffAxE (ours)", space: "O(10^17)", sps: vec![], time: 0.0 },
    ];

    for (wi, w) in engine.stats.workloads.iter().take(n_workloads).enumerate() {
        let g = w.gemm;
        let seed = 100 + wi as u64;
        let rand = edp::random_edp(&g, budget, seed);
        let outs = [
            rand.clone(),
            edp::vanilla_bo_edp(&g, &bo_opts, seed),
            edp::latent_bo_edp(&engine, &g, &bo_opts, seed)?,
            edp::dosa_edp(&g, &gd_opts, seed),
            edp::polaris_edp(&engine, &g, &gd_opts, seed)?,
            edp::diffaxe_edp(&engine, &g, n_per_class, seed as u32)?,
        ];
        for (m, o) in methods.iter_mut().zip(&outs) {
            m.sps.push(rand.best_edp / o.best_edp);
            m.time += o.search_time_s;
        }
    }

    let mut t = Table::new(&["Baseline", "Design Space", "SP (geo-mean, up)", "Search Time (s, down)"]);
    for m in &methods {
        t.row(&[
            m.name.to_string(),
            m.space.to_string(),
            fnum(geomean(&m.sps)),
            fnum(m.time / n_workloads as f64),
        ]);
    }
    println!("{}", t.render());
    let sp_diff = geomean(&methods[5].sps);
    let sp_vaesa = geomean(&methods[2].sps);
    println!(
        "paper-shape checks: SP DiffAxE {:.2} vs VAESA {:.2} (paper 1.12 vs 1.02); \
         DOSA/Polaris below random: {} (paper: yes)",
        sp_diff,
        sp_vaesa,
        geomean(&methods[3].sps) < 1.0 && geomean(&methods[4].sps) < 1.0
    );
    Ok(())
}
