//! Table IV: EDP-oriented DSE — SP (= EDP_random / EDP_method, higher
//! better) and search time for random / vanilla BO / VAESA / DOSA /
//! Polaris / DiffAxE, all selected by `OptimizerKind` through one
//! `Session`.
//!
//! Paper shape: SP(DiffAxE) > SP(VAESA) > 1 ≳ SP(vanilla BO) ≫ SP of the
//! coarse-space GD methods (DOSA, Polaris), with DiffAxE orders of
//! magnitude faster than the BO methods.

use diffaxe::baselines::{BoOptions, GdOptions};
use diffaxe::dse::{Budget, Objective, OptimizerKind, Session};
use diffaxe::models::DiffAxE;
use diffaxe::util::bench::{banner, BenchScale};
use diffaxe::util::stats::geomean;
use diffaxe::util::table::{fnum, Table};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    banner("Table IV", "EDP-oriented DSE (SP vs random search)");
    let dir = Path::new("artifacts");
    if !DiffAxE::artifacts_present(dir) {
        println!("SKIP: run `make artifacts` first");
        return Ok(());
    }
    let mut session = Session::load(dir)?;
    let scale = BenchScale::from_env();
    let stats = session.engine().unwrap().stats.clone();
    let n_workloads = scale.pick(2, 6, stats.workloads.len());
    let n_per_class = scale.pick(8, 32, 1000); // paper: 1000
    let n_classes = stats.n_power * stats.n_perf;
    let total_budget = n_per_class * n_classes;
    session.bo_opts = BoOptions {
        n_init: scale.pick(6, 10, 16),
        budget: scale.pick(15, 40, 150),
        pool: scale.pick(64, 200, 512),
        ..Default::default()
    };
    session.gd_opts =
        GdOptions { steps: scale.pick(10, 25, 60), restarts: scale.pick(2, 3, 4), ..Default::default() };
    let bo_evals = session.bo_opts.budget;

    struct Agg {
        kind: OptimizerKind,
        name: &'static str,
        space: &'static str,
        budget: Budget,
        sps: Vec<f64>,
        time: f64,
    }
    let mut methods = vec![
        Agg {
            kind: OptimizerKind::RandomSearch,
            name: "Random Search",
            space: "O(10^17)",
            budget: Budget::evals(total_budget),
            sps: vec![],
            time: 0.0,
        },
        Agg {
            kind: OptimizerKind::VanillaBo,
            name: "Vanilla BO",
            space: "O(10^17)",
            budget: Budget::evals(bo_evals),
            sps: vec![],
            time: 0.0,
        },
        Agg {
            kind: OptimizerKind::LatentBo,
            name: "VAESA (latent BO)",
            space: "O(10^17)",
            budget: Budget::evals(bo_evals),
            sps: vec![],
            time: 0.0,
        },
        Agg {
            kind: OptimizerKind::DosaGd,
            name: "DOSA (vanilla GD)",
            space: "~O(10^7)",
            budget: Budget::evals(1_000_000),
            sps: vec![],
            time: 0.0,
        },
        Agg {
            kind: OptimizerKind::Polaris,
            name: "Polaris (latent GD)",
            space: "~O(10^7)",
            budget: Budget::evals(1_000_000),
            sps: vec![],
            time: 0.0,
        },
        Agg {
            kind: OptimizerKind::DiffAxE,
            name: "DiffAxE (ours)",
            space: "O(10^17)",
            budget: Budget::evals(total_budget).with_per_class(n_per_class),
            sps: vec![],
            time: 0.0,
        },
    ];

    for (wi, w) in stats.workloads.iter().take(n_workloads).enumerate() {
        let obj = Objective::MinEdp { g: w.gemm };
        let seed = 100 + wi as u64;
        let mut outs = Vec::with_capacity(methods.len());
        for m in &methods {
            outs.push(session.search(m.kind, &obj, &m.budget, seed)?);
        }
        let rand_best = outs[0].best_score(); // SP normalizer (methods[0] = random)
        for (m, out) in methods.iter_mut().zip(&outs) {
            m.sps.push(rand_best / out.best_score());
            m.time += out.search_time_s;
        }
    }

    let mut t = Table::new(&["Baseline", "Design Space", "SP (geo-mean, up)", "Search Time (s, down)"]);
    for m in &methods {
        t.row(&[
            m.name.to_string(),
            m.space.to_string(),
            fnum(geomean(&m.sps)),
            fnum(m.time / n_workloads as f64),
        ]);
    }
    println!("{}", t.render());
    let sp_diff = geomean(&methods[5].sps);
    let sp_vaesa = geomean(&methods[2].sps);
    println!(
        "paper-shape checks: SP DiffAxE {:.2} vs VAESA {:.2} (paper 1.12 vs 1.02); \
         DOSA/Polaris below random: {} (paper: yes)",
        sp_diff,
        sp_vaesa,
        geomean(&methods[3].sps) < 1.0 && geomean(&methods[4].sps) < 1.0
    );
    Ok(())
}
